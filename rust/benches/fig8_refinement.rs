//! Fig 8 — Recall@10 vs refinement ratio (SSD reads / final top-k).
//!
//! Paper claim: recovering the true top-10 with 99% probability from a
//! 100-candidate PQ list takes ~70 full-precision fetches without FaTRQ
//! (yellow curve: scan the PQ-ranked list in order) but only ~25 with the
//! FaTRQ-ranked queue — a 2.8x refinement reduction.

use fatrq::bench_support as bs;
use fatrq::config::{
    AccelRerank, ArrivalDist, DatasetConfig, FarPlacement, FaultConfig, IndexConfig, IndexKind,
    LanePolicy, OutageSpec, QuantConfig, RefineConfig, RefineMode, SystemConfig, TenantSpec,
};
use fatrq::coordinator::{
    build_system_with, ground_truth_for, report_from_outcomes, QueryEngine, ShardedEngine,
};
use fatrq::metrics::recall_at_k;
use fatrq::refine::{FirstOrderCand, ProgressiveEstimator};
use fatrq::util::topk::{Scored, TopK};
use fatrq::util::l2_sq;
use fatrq::vecstore::synthesize;
use std::sync::Arc;

/// recall@10 when fetching exactly the first `reads` entries of `order`.
fn recall_with_reads(
    sys: &fatrq::coordinator::BuiltSystem,
    query: &[f32],
    order: &[Scored],
    truth: &[Scored],
    reads: usize,
) -> f64 {
    let mut top = TopK::new(10);
    for c in order.iter().take(reads) {
        top.push(l2_sq(query, sys.dataset.vector(c.id as usize)), c.id);
    }
    fatrq::metrics::recall_at_k(&top.into_sorted(), truth, 10)
}

fn main() {
    // `--quick` (CI smoke): skip the full-corpus sweep, run only the
    // 2-shard scatter/gather serving row so the shard path is exercised
    // on every push.
    let quick = std::env::args().any(|a| a == "--quick");
    if !quick {
        refinement_ratio_sweep();
    }
    serving_section(quick);
    pipelined_section(quick);
    accel_batch_section(quick);
    lanes_and_qos_section(quick);
    faults_section(quick);
    outofcore_section(quick);
    farpool_section(quick);
}

fn refinement_ratio_sweep() {
    println!("# Fig 8 — recall@10 vs refinement ratio (reads / k)\n");
    let dataset = bs::bench_dataset();
    let sys = bs::build_bench_system(IndexKind::Ivf, dataset);
    let est = ProgressiveEstimator::new(&sys.trq, sys.cal.clone());

    let nq = sys.dataset.num_queries();
    // Per query: the top-100 PQ candidates, ranked two ways. Ground truth
    // is the exact top-10 *within the candidate list* (the paper's
    // protocol: "collected the true top-100 based on PQ distances and
    // examined reranking behavior" — recall is relative to what full
    // refinement of the list would recover).
    let mut pq_orders = Vec::with_capacity(nq);
    let mut fatrq_orders = Vec::with_capacity(nq);
    let mut truths = Vec::with_capacity(nq);
    for q in 0..nq {
        let query = sys.dataset.query(q);
        let cands = sys.index.as_ann().search(query, 100);
        let refined = est.refine_list(query, &cands);
        let mut exact_in_list = TopK::new(10);
        for c in &cands {
            exact_in_list.push(l2_sq(query, sys.dataset.vector(c.id as usize)), c.id);
        }
        truths.push(exact_in_list.into_sorted());
        pq_orders.push(cands);
        fatrq_orders.push(refined);
    }

    bs::header(&["reads", "ratio (reads/k)", "recall PQ-order", "recall FaTRQ-order"]);
    let mut pq_99 = None;
    let mut fatrq_99 = None;
    for reads in [10usize, 15, 20, 25, 30, 35, 40, 50, 60, 70, 80, 90, 100] {
        let mut r_pq = 0.0;
        let mut r_fatrq = 0.0;
        for q in 0..nq {
            let query = sys.dataset.query(q);
            r_pq += recall_with_reads(&sys, query, &pq_orders[q], &truths[q], reads);
            r_fatrq += recall_with_reads(&sys, query, &fatrq_orders[q], &truths[q], reads);
        }
        r_pq /= nq as f64;
        r_fatrq /= nq as f64;
        if r_pq >= 0.99 && pq_99.is_none() {
            pq_99 = Some(reads);
        }
        if r_fatrq >= 0.99 && fatrq_99.is_none() {
            fatrq_99 = Some(reads);
        }
        bs::row(&[
            reads.to_string(),
            format!("{:.1}", reads as f64 / 10.0),
            format!("{r_pq:.4}"),
            format!("{r_fatrq:.4}"),
        ]);
    }

    // The ratio the paper headlines. 99% of the *achievable* recall — the
    // candidate list itself caps recall below 1.0.
    let max_reads_pq = pq_99.unwrap_or(100);
    let max_reads_fatrq = fatrq_99.unwrap_or(100);
    println!("\nreads to reach 99% recall: PQ-order {max_reads_pq}, FaTRQ-order {max_reads_fatrq}");
    println!(
        "refinement reduction: {:.1}x (paper: 70 -> 25 = 2.8x)",
        max_reads_pq as f64 / max_reads_fatrq as f64
    );

    // --- Early-exit far-memory savings (the §I claim, measured) ---
    // The full FaTRQ ranking above streams every candidate's TRQ record.
    // The progressive walk stops once the remaining candidates are provably
    // outside the top-k, so the *far-memory* reads themselves shrink.
    let mut streamed_total = 0usize;
    let mut recall_ee = 0.0f64;
    let mut bound = TopK::new(10);
    let mut refined = Vec::new();
    for q in 0..nq {
        let query = sys.dataset.query(q);
        let cands = &pq_orders[q];
        let mut ordered: Vec<FirstOrderCand> = cands
            .iter()
            .map(|c| FirstOrderCand {
                id: c.id,
                d0: c.dist,
                d1: est.estimate_first_order(c.id as usize, c.dist),
            })
            .collect();
        ordered.sort_by(|a, b| a.d1.partial_cmp(&b.d1).unwrap().then(a.id.cmp(&b.id)));
        let stats = est.refine_progressive_into(
            query,
            &ordered,
            10,
            sys.margin_first,
            sys.margin,
            &mut bound,
            &mut refined,
        );
        streamed_total += stats.streamed;
        refined.sort_by(|a, b| a.dist.partial_cmp(&b.dist).unwrap().then(a.id.cmp(&b.id)));
        let mut top = TopK::new(10);
        for c in &refined {
            top.push(l2_sq(query, sys.dataset.vector(c.id as usize)), c.id);
        }
        recall_ee += fatrq::metrics::recall_at_k(&top.into_sorted(), &truths[q], 10);
    }
    let mean_streamed = streamed_total as f64 / nq as f64;
    println!(
        "\nearly-exit walk: {:.1} far-memory reads/query out of 100 candidates \
         ({:.1}x stream reduction), recall-in-list {:.4}",
        mean_streamed,
        100.0 / mean_streamed.max(1e-9),
        recall_ee / nq as f64
    );
}

/// Serving corpus for the scatter/gather rows (smaller than the sweep
/// corpus: up to 15 shard systems get built in full mode).
fn serving_config(quick: bool) -> SystemConfig {
    SystemConfig {
        dataset: DatasetConfig {
            dim: if quick { 32 } else { 64 },
            count: if quick { 2000 } else { 8000 * bs::scale() },
            clusters: if quick { 16 } else { 64 },
            noise: 0.35,
            query_noise: 1.0,
            queries: if quick { 32 } else { 64 },
            seed: 88,
        },
        quant: QuantConfig {
            pq_m: if quick { 8 } else { 16 },
            pq_nbits: 6,
            kmeans_iters: 6,
            train_sample: 2048,
        },
        index: IndexConfig {
            kind: IndexKind::Ivf,
            nlist: if quick { 16 } else { 64 },
            nprobe: if quick { 8 } else { 16 },
            ..Default::default()
        },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.25,
            calib_sample: 0.01,
            ..Default::default()
        },
        ..Default::default()
    }
}

/// Batch serving over sharded scatter/gather, contention on/off: the
/// honest-throughput rows. With the shared timeline on, batch ≥ 8 must
/// show nonzero queueing (batch latency strictly above the
/// independent-device model); at batch 1 the two models agree.
fn serving_section(quick: bool) {
    println!("\n# Sharded scatter/gather serving (fatrq-hw, one shared far-memory device)\n");
    let cfg = serving_config(quick);
    let dataset = synthesize(&cfg.dataset);
    let truth = ground_truth_for(&dataset, cfg.refine.k);
    let dim = dataset.dim;
    let nq = dataset.num_queries();
    // Quick mode still covers 1 shard so the "unsharded batch 1 == the
    // independent model" assertion below runs on every CI push, not just
    // in full runs.
    let shard_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let batches: &[usize] = if quick { &[1, 8] } else { &[1, 8, 32] };

    bs::header(&[
        "shards",
        "batch",
        "contention",
        "recall@10",
        "mean(us)",
        "p99(us)",
        "queue(us)",
        "model-qps",
    ]);
    for &shards in shard_counts {
        let mut engine = ShardedEngine::from_dataset(&cfg, &dataset, shards)
            .expect("shard build");
        for &batch in batches {
            for contention in [false, true] {
                engine.set_shared_timeline(contention);
                let wall0 = std::time::Instant::now();
                let mut outs = Vec::with_capacity(nq);
                let mut b = 0usize;
                while b < nq {
                    let e = (b + batch).min(nq);
                    outs.extend(engine.run(&dataset.queries[b * dim..e * dim]));
                    b = e;
                }
                let wall_ns = wall0.elapsed().as_nanos() as f64;
                let rep = report_from_outcomes(
                    &outs,
                    &truth,
                    cfg.refine.k,
                    engine.threads(),
                    wall_ns,
                    if contention { "contended" } else { "independent" },
                );
                // The simulated-contention contract (host-measured stage
                // times vary run to run; queue_ns is deterministic): a
                // single unsharded query reduces to the independent model
                // exactly; at batch >= 8 every query's latency carries a
                // queueing term on top of it. (With N >= 2 shards even a
                // solo query fans N concurrent streams onto the one
                // device, so a small queue term there is the honest
                // answer, not a bug.)
                if contention && batch == 1 && shards == 1 {
                    assert_eq!(
                        rep.breakdown.queue_ns, 0.0,
                        "unsharded batch 1 must match the independent device model"
                    );
                }
                if contention && batch >= 8 {
                    assert!(
                        rep.breakdown.queue_ns > 0.0,
                        "batch {batch} at {shards} shards must queue on the shared device"
                    );
                }
                bs::row(&[
                    shards.to_string(),
                    batch.to_string(),
                    if contention { "on".into() } else { "off".to_string() },
                    format!("{:.4}", rep.mean_recall),
                    format!("{:.1}", rep.mean_latency_ns / 1e3),
                    format!("{:.1}", rep.p99_ns / 1e3),
                    format!("{:.2}", rep.breakdown.queue_ns / 1e3),
                    format!("{:.0}", rep.qps),
                ]);
            }
        }
    }
    println!(
        "\nbatch 1 rows: contention on == off (shared timeline reduces to the \
         independent model); batch >= 8: contended latency strictly above it \
         (queue(us) > 0) — asserted at runtime."
    );
}

/// Pipelined stage-graph serving: sweep pipeline depth × batch size over
/// one captured stage profile per batch (profiles are deterministic
/// functions of the functional results, so every number in this section
/// is host-independent). Runtime contracts, asserted on every run:
///
/// - depth 1 == the sequential engine: bit-identical top-k, zero device
///   queueing, makespan == the serialized per-query sum;
/// - depth ≥ 4 overlaps stages: simulated makespan strictly below the
///   serialized schedule (overlap gain > 1x), never above it
///   (work conservation).
///
/// A second table drives open-loop arrivals (`sim.arrival_qps`-style)
/// through the same profiles: p50/p95/p99 grow with offered load once
/// admission waits stack up.
fn pipelined_section(quick: bool) {
    println!("\n# Pipelined stage-graph serving (fatrq-hw, shared far-memory + SSD queues)\n");
    let mut cfg = serving_config(quick);
    cfg.sim.shared_timeline = true;
    let dataset = synthesize(&cfg.dataset);
    let truth = ground_truth_for(&dataset, cfg.refine.k);
    let dim = dataset.dim;
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).expect("build"));
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let k = cfg.refine.k;

    let batches: &[usize] = if quick { &[8, 16] } else { &[8, 32, 64] };
    let depths: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };

    bs::header(&[
        "batch",
        "depth",
        "recall@10",
        "mean(us)",
        "p95(us)",
        "p99(us)",
        "queue(us)",
        "makespan(us)",
        "overlap-gain",
    ]);
    for &batch in batches {
        let batch = batch.min(dataset.num_queries());
        let queries = &dataset.queries[..batch * dim];
        let profile = engine.profile_with(engine.params(), queries);
        let serialized = profile.schedule(1, 0.0).1.makespan_ns;
        for &depth in depths {
            let (outs, report) = profile.schedule(depth, 0.0);
            // --- runtime contracts ---
            if depth == 1 {
                let service_sum: f64 = report.timings.iter().map(|t| t.service_ns).sum();
                for (q, out) in outs.iter().enumerate() {
                    let seq = engine.query(&queries[q * dim..(q + 1) * dim]);
                    assert_eq!(
                        out.topk, seq.topk,
                        "depth-1 pipelining must be bit-identical to the sequential engine (query {q})"
                    );
                    assert_eq!(out.breakdown.queue_ns, 0.0, "depth 1 must not queue");
                }
                assert!(
                    (report.makespan_ns - service_sum).abs() <= 1e-9 * service_sum,
                    "depth-1 makespan {} != serialized service sum {service_sum}",
                    report.makespan_ns
                );
            }
            if depth >= 4 {
                assert!(
                    report.makespan_ns < serialized,
                    "depth {depth} must overlap stages: makespan {} !< serialized {serialized}",
                    report.makespan_ns
                );
                assert!(
                    report.makespan_ns <= serialized * (1.0 + 1e-9),
                    "work conservation violated at depth {depth}"
                );
            }
            let recall: f64 = outs
                .iter()
                .enumerate()
                .map(|(q, o)| recall_at_k(&o.topk, &truth[q], k))
                .sum::<f64>()
                / batch as f64;
            let queue: f64 =
                outs.iter().map(|o| o.breakdown.queue_ns).sum::<f64>() / batch as f64;
            bs::row(&[
                batch.to_string(),
                depth.to_string(),
                format!("{recall:.4}"),
                format!("{:.1}", report.mean_latency_ns / 1e3),
                format!("{:.1}", report.p95_ns / 1e3),
                format!("{:.1}", report.p99_ns / 1e3),
                format!("{queue:.2}"),
                format!("{:.1}", report.makespan_ns / 1e3),
                format!("{:.2}x", serialized / report.makespan_ns.max(1e-9)),
            ]);
        }
    }
    println!(
        "\ndepth 1 == sequential engine (bit-identical top-k, queue == 0, makespan == \
         serialized) and overlap gain > 1x at depth >= 4 — asserted at runtime."
    );

    // --- open-loop arrivals: tail latency vs offered load ---
    println!("\n## Open-loop arrivals (depth 8, p50/p95/p99 include admission wait)\n");
    let batch = dataset.num_queries();
    let profile = engine.profile_with(engine.params(), &dataset.queries);
    // Offered loads bracketing saturation: mean service sets the knee.
    let mean_service_ns = profile.schedule(1, 0.0).1.makespan_ns / batch as f64;
    let sat_qps = 1e9 / mean_service_ns.max(1.0);
    bs::header(&["arrival-qps", "load", "p50(us)", "p95(us)", "p99(us)", "makespan(us)"]);
    let mut last_p99 = 0.0f64;
    let mut first_p99 = f64::NAN;
    for load in [0.2, 1.0, 5.0] {
        let qps = sat_qps * load;
        let (_, rep) = profile.schedule(8, qps);
        if first_p99.is_nan() {
            first_p99 = rep.p99_ns;
        }
        last_p99 = rep.p99_ns;
        bs::row(&[
            format!("{qps:.0}"),
            format!("{load:.1}"),
            format!("{:.1}", rep.p50_ns / 1e3),
            format!("{:.1}", rep.p95_ns / 1e3),
            format!("{:.1}", rep.p99_ns / 1e3),
            format!("{:.1}", rep.makespan_ns / 1e3),
        ]);
    }
    assert!(
        last_p99 >= first_p99,
        "tail latency must not shrink as offered load grows ({last_p99} < {first_p99})"
    );
    println!("\ntail grows with offered load past saturation — asserted at runtime.");
}

/// Accelerator batch tier: CPU-only vs CPU+accel rerank placement, and
/// the admission-time coalescing sweep. One captured stage profile (the
/// functional results never move — rerank placement is a timing concern
/// only), host-independent numbers. Runtime contracts, asserted on every
/// run:
///
/// - **batch-1 == the sequential per-query accel timeline**: with
///   `accel.batch_max = 1` every batch seals at its first joiner, so the
///   coalescing window is structurally inert — a zero window and the
///   sweep window produce bit-identical clocks, and one query in flight
///   (depth 1) never queues at the transfer link or the device.
/// - **coalescing gain > 1x at depth >= 4**: singleton launches pay the
///   fixed launch overhead per task, which dominates the device's
///   per-item cost; coalesced admission amortizes it and the makespan
///   drops strictly below the batch-1 makespan at the same depth.
fn accel_batch_section(quick: bool) {
    println!("\n# Accelerator batch tier (fatrq-hw, device rerank behind a PCIe/CXL staging queue)\n");
    let mut cfg = serving_config(quick);
    cfg.sim.shared_timeline = true;
    // NVMe-array IOPS headroom (4x one 990 Pro) so the device launch
    // overhead — not the fetch path — is the batch-1 bottleneck: the
    // regime the coalescing tier targets, and what makes the gain
    // contract below a statement about amortization rather than about
    // an incidentally IOPS-bound fetch stage.
    cfg.sim.ssd_kiops = 4800.0;
    let dataset = synthesize(&cfg.dataset);
    let nq = dataset.num_queries();
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).expect("build"));
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);

    // (depth, batch_max) sweep points. Caps stay at or below half the
    // depth so sealing happens by count and the pipeline never waits on
    // the coalescing window except at the tail of the run.
    let sweep: &[(usize, usize)] = if quick {
        &[(1, 1), (4, 1), (4, 2), (8, 1), (8, 4)]
    } else {
        &[(1, 1), (4, 1), (4, 2), (8, 1), (8, 4), (16, 1), (16, 8)]
    };
    let window_us = 200.0;

    // CPU-only reference rows (the pre-accel serving path), then the
    // device sweep against them.
    bs::header(&[
        "rerank",
        "depth",
        "batch-max",
        "mean(us)",
        "p99(us)",
        "mean-batch",
        "dev-queue(us)",
        "makespan(us)",
        "coalesce-gain",
    ]);
    for &depth in &[1usize, 4, 8, 16] {
        if !sweep.iter().any(|&(d, _)| d == depth) {
            continue;
        }
        let (_, rep) = profile.schedule(depth, 0.0);
        bs::row(&[
            "cpu".to_string(),
            depth.to_string(),
            "-".to_string(),
            format!("{:.1}", rep.mean_latency_ns / 1e3),
            format!("{:.1}", rep.p99_ns / 1e3),
            "-".to_string(),
            "-".to_string(),
            format!("{:.1}", rep.makespan_ns / 1e3),
            "-".to_string(),
        ]);
    }
    profile.set_accel_rerank(AccelRerank::Batch);
    let mut singleton_ms = std::collections::BTreeMap::new();
    for &(depth, max) in sweep {
        profile.set_accel_batch_max(max);
        profile.set_accel_batch_window_us(window_us);
        let (outs, rep) = profile.schedule(depth, 0.0);
        // --- runtime contracts ---
        assert!(rep.accel.active, "accel tier inactive in the accel sweep");
        if max == 1 {
            // The coalescing window is structurally inert at batch-1:
            // the zero-window clock must be bit-identical.
            profile.set_accel_batch_window_us(0.0);
            let (_, zero) = profile.schedule(depth, 0.0);
            profile.set_accel_batch_window_us(window_us);
            assert_eq!(
                rep.makespan_ns, zero.makespan_ns,
                "batch-1 diverged from the sequential per-query accel timeline at depth {depth}"
            );
            for q in 0..nq {
                assert_eq!(rep.timings[q].done_ns, zero.timings[q].done_ns, "query {q}");
            }
            assert!(rep.accel.max_batch <= 1, "batch-1 coalesced at depth {depth}");
            singleton_ms.insert(depth, rep.makespan_ns);
        }
        if depth == 1 {
            for (q, out) in outs.iter().enumerate() {
                assert_eq!(
                    out.breakdown.queue_ns, 0.0,
                    "depth 1 must not queue at the device (query {q})"
                );
            }
        }
        if max >= 2 && depth >= 4 {
            let single = singleton_ms[&depth];
            assert!(
                rep.makespan_ns < single,
                "coalescing gain <= 1x at depth {depth}: batch-{max} makespan {} !< \
                 batch-1 makespan {single}",
                rep.makespan_ns
            );
            assert!(
                rep.accel.mean_batch() > 1.0,
                "depth {depth} batch-{max}: admission never coalesced"
            );
        }
        let gain = singleton_ms[&depth] / rep.makespan_ns.max(1e-9);
        bs::row(&[
            "batch".to_string(),
            depth.to_string(),
            max.to_string(),
            format!("{:.1}", rep.mean_latency_ns / 1e3),
            format!("{:.1}", rep.p99_ns / 1e3),
            format!("{:.2}", rep.accel.mean_batch()),
            format!("{:.2}", rep.accel.mean_accel_queue_ns() / 1e3),
            format!("{:.1}", rep.makespan_ns / 1e3),
            format!("{gain:.2}x"),
        ]);
    }
    profile.set_accel_rerank(AccelRerank::Cpu);
    println!(
        "\nbatch-1 == sequential per-query accel timeline bit-for-bit (window inert, depth 1 \
         never queues) and coalescing gain > 1x at depth >= 4 — asserted at runtime."
    );
}

/// Lanes and QoS: the unified resource-server scheduler. Three tables
/// over one captured stage profile each (host-independent numbers), with
/// runtime contracts asserted on every run:
///
/// - **lanes × depth** — compute stages occupy a bounded CPU lane server
///   (`serve.cpu_lanes`). Unbounded lanes reproduce the pre-lane clock
///   bit-for-bit (asserted against an effectively-infinite finite lane
///   count), depth-1 stays the sequential engine at any lane count, and
///   bounded lanes never break work conservation.
/// - **Poisson vs uniform arrivals** — seeded exponential gaps
///   (`sim.arrival_dist = "poisson"`) stress burstiness; per
///   distribution, the tail must grow with offered load.
/// - **2-tenant flood isolation** — a flooding tenant against a
///   lightly-loaded high-weight tenant under weighted-fair admission
///   (`serve.tenants`): the light tenant's admission wait is bounded by
///   one in-flight query turn, and its tail beats the FIFO (no-QoS)
///   schedule of the identical workload.
fn lanes_and_qos_section(quick: bool) {
    println!("\n# Lanes and QoS (unified resource-server scheduling)\n");
    let mut cfg = serving_config(quick);
    cfg.sim.shared_timeline = true;
    let dataset = synthesize(&cfg.dataset);
    let nq = dataset.num_queries();
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).expect("build"));
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
    // SW refinement keeps the most compute on CPU lanes.
    let params = fatrq::coordinator::QueryParams::from_config(&cfg)
        .with_mode(RefineMode::FatrqSw);

    // ---- lanes × depth sweep ----
    println!("## CPU lanes x pipeline depth (fatrq-sw, batch {nq})\n");
    let mut profile = engine.profile_with(&params, &dataset.queries);
    let m1 = {
        profile.set_cpu_lanes(0);
        profile.schedule(1, 0.0).1.makespan_ns
    };
    let lane_counts: &[usize] = if quick { &[2, 0] } else { &[2, 4, 0] };
    let depths: &[usize] = if quick { &[1, 4] } else { &[1, 4, 16] };
    bs::header(&["lanes", "depth", "mean(us)", "p99(us)", "queue(us)", "makespan(us)", "vs-serialized"]);
    for &lanes in lane_counts {
        profile.set_cpu_lanes(lanes);
        for &depth in depths {
            let (outs, rep) = profile.schedule(depth, 0.0);
            // --- runtime contracts ---
            if lanes == 0 {
                // Unbounded lanes == a finite lane count larger than any
                // possible compute concurrency, bit-for-bit.
                profile.set_cpu_lanes(nq + 8);
                let (_, big) = profile.schedule(depth, 0.0);
                assert_eq!(
                    rep.makespan_ns, big.makespan_ns,
                    "lanes=inf diverged from effectively-infinite lanes at depth {depth}"
                );
                for q in 0..nq {
                    assert_eq!(rep.timings[q].done_ns, big.timings[q].done_ns, "query {q}");
                }
                profile.set_cpu_lanes(0);
            }
            if depth == 1 {
                // Depth 1 is the sequential engine at any lane count: one
                // in-flight query runs one compute stage at a time.
                for (q, out) in outs.iter().enumerate() {
                    assert_eq!(
                        out.breakdown.queue_ns, 0.0,
                        "depth 1 must not queue (lanes {lanes}, query {q})"
                    );
                }
            }
            assert!(
                rep.makespan_ns <= m1 * (1.0 + 1e-9),
                "lanes {lanes} depth {depth}: work conservation violated ({} > {m1})",
                rep.makespan_ns
            );
            if lanes > 0 && lanes <= 2 && depth >= 4 {
                // >= 4 co-admitted front stages on <= 2 lanes must wait.
                // Check against a private-device schedule so queue_ns is
                // lane wait alone (the shared-timeline run above would
                // pass on device contention even with broken lane
                // accounting).
                profile.set_shared_timeline(false);
                let (lane_outs, _) = profile.schedule(depth, 0.0);
                profile.set_shared_timeline(true);
                let cpu_queued: f64 =
                    lane_outs.iter().map(|o| o.breakdown.queue_ns).sum();
                assert!(
                    cpu_queued > 0.0,
                    "{lanes} lanes under depth {depth} must charge CPU queueing"
                );
            }
            let queue: f64 =
                outs.iter().map(|o| o.breakdown.queue_ns).sum::<f64>() / nq as f64;
            bs::row(&[
                if lanes == 0 { "inf".to_string() } else { lanes.to_string() },
                depth.to_string(),
                format!("{:.1}", rep.mean_latency_ns / 1e3),
                format!("{:.1}", rep.p99_ns / 1e3),
                format!("{queue:.2}"),
                format!("{:.1}", rep.makespan_ns / 1e3),
                format!("{:.2}x", m1 / rep.makespan_ns.max(1e-9)),
            ]);
        }
    }
    println!(
        "\nlanes=inf == effectively-infinite lanes bit-for-bit, depth 1 == sequential at \
         any lane count, bounded lanes stay work-conserving — asserted at runtime."
    );

    // ---- lane admission policy: FCFS vs shortest-expected-first ----
    println!("\n## Lane admission policy at small lane counts (fatrq-sw, depth 8)\n");
    bs::header(&["lanes", "policy", "mean(us)", "p99(us)", "queue(us)", "makespan(us)"]);
    for &lanes in &[1usize, 2] {
        profile.set_cpu_lanes(lanes);
        let mut fcfs_topk: Vec<Vec<_>> = Vec::new();
        for policy in [LanePolicy::Fcfs, LanePolicy::Ssf] {
            profile.set_lane_policy(policy);
            let (outs, rep) = profile.schedule(8, 0.0);
            // --- runtime contracts ---
            assert!(
                rep.makespan_ns <= m1 * (1.0 + 1e-9),
                "{policy:?} on {lanes} lanes: work conservation violated"
            );
            for (q, out) in outs.iter().enumerate() {
                match policy {
                    LanePolicy::Fcfs => fcfs_topk.push(out.topk.clone()),
                    LanePolicy::Ssf => assert_eq!(
                        fcfs_topk[q], out.topk,
                        "lane policy changed the top-k (lanes {lanes}, query {q})"
                    ),
                }
            }
            let queue: f64 =
                outs.iter().map(|o| o.breakdown.queue_ns).sum::<f64>() / nq as f64;
            bs::row(&[
                lanes.to_string(),
                policy.name().to_string(),
                format!("{:.1}", rep.mean_latency_ns / 1e3),
                format!("{:.1}", rep.p99_ns / 1e3),
                format!("{queue:.2}"),
                format!("{:.1}", rep.makespan_ns / 1e3),
            ]);
        }
    }
    profile.set_lane_policy(LanePolicy::Fcfs);
    profile.set_cpu_lanes(0);
    println!(
        "\nshortest-expected-service-first reorders lane admission only: identical top-k, \
         work conservation intact — asserted at runtime."
    );

    // ---- Poisson vs uniform arrivals ----
    println!("\n## Poisson vs uniform arrivals (depth 8, lanes inf)\n");
    profile.set_cpu_lanes(0);
    let mean_service_ns = m1 / nq as f64;
    let sat_qps = 1e9 / mean_service_ns.max(1.0);
    bs::header(&["dist", "load", "p50(us)", "p95(us)", "p99(us)", "makespan(us)"]);
    for dist in [ArrivalDist::Uniform, ArrivalDist::Poisson] {
        profile.set_arrival_dist(dist);
        let mut last_p99 = 0.0f64;
        for load in [0.2, 1.0, 5.0] {
            let qps = sat_qps * load;
            let (_, rep) = profile.schedule(8, qps);
            assert!(
                rep.p99_ns >= last_p99,
                "{}: tail shrank as offered load grew ({} < {last_p99})",
                dist.name(),
                rep.p99_ns
            );
            last_p99 = rep.p99_ns;
            bs::row(&[
                dist.name().to_string(),
                format!("{load:.1}"),
                format!("{:.1}", rep.p50_ns / 1e3),
                format!("{:.1}", rep.p95_ns / 1e3),
                format!("{:.1}", rep.p99_ns / 1e3),
                format!("{:.1}", rep.makespan_ns / 1e3),
            ]);
        }
        // Same seed, same rate: the Poisson schedule is reproducible.
        let (_, a) = profile.schedule(8, sat_qps);
        let (_, b) = profile.schedule(8, sat_qps);
        assert_eq!(a.p99_ns, b.p99_ns, "{} schedule not reproducible", dist.name());
    }
    profile.set_arrival_dist(ArrivalDist::Uniform);
    println!("\nper-distribution tails grow with offered load — asserted at runtime.");

    // ---- 2-tenant flood isolation ----
    println!("\n## 2-tenant flood isolation (depth 2, weighted-fair admission)\n");
    let nflood = nq * 3 / 4;
    let nlight = nq - nflood;
    let tags: Vec<usize> = (0..nq).map(|q| usize::from(q >= nflood)).collect();
    // Floods arrive at t = 0; light queries trickle in while the flood
    // backlog drains.
    let mut trace = vec![0.0; nflood];
    for i in 0..nlight {
        trace.push(m1 * 0.1 * (i + 1) as f64 / nlight as f64);
    }
    profile.set_arrival_trace(trace);
    let light_tail = |rep: &fatrq::coordinator::ServeReport| {
        rep.timings[nflood..].iter().map(|t| t.latency_ns()).fold(0.0f64, f64::max)
    };
    // FIFO (no QoS) baseline of the identical workload.
    profile.set_tenants(Vec::new(), Vec::new());
    let (_, fifo) = profile.schedule(2, 0.0);
    let fifo_light = light_tail(&fifo);
    // Weighted-fair: flood weight 1, light tenant weight 8.
    profile.set_tenants(
        vec![
            TenantSpec { name: "flood".into(), weight: 1.0, quota: 0, trace: None },
            TenantSpec { name: "latency".into(), weight: 8.0, quota: 0, trace: None },
        ],
        tags,
    );
    let (_, wfq) = profile.schedule(2, 0.0);
    assert_eq!(wfq.tenants.len(), 2);
    // Isolation bound, runtime-asserted: a light query waits at most one
    // in-flight query turn per concurrently-waiting light query — its
    // own tenant's queue, never the flood's backlog.
    let max_turn =
        wfq.timings.iter().map(|t| t.done_ns - t.admit_ns).fold(0.0f64, f64::max);
    for (i, t) in wfq.timings[nflood..].iter().enumerate() {
        assert!(
            t.admit_ns - t.arrival_ns <= nlight as f64 * max_turn + 1.0,
            "light query {i}: admission wait {} exceeds {nlight} slot turns {max_turn}",
            t.admit_ns - t.arrival_ns
        );
    }
    let wfq_light = light_tail(&wfq);
    assert!(
        wfq_light < fifo_light,
        "weighted-fair light tail {wfq_light} !< FIFO {fifo_light}"
    );
    bs::header(&["schedule", "tenant", "queries", "p50(us)", "p95(us)", "p99(us)"]);
    for t in &wfq.tenants {
        bs::row(&[
            "weighted-fair".to_string(),
            t.name.clone(),
            t.queries.to_string(),
            format!("{:.1}", t.p50_ns / 1e3),
            format!("{:.1}", t.p95_ns / 1e3),
            format!("{:.1}", t.p99_ns / 1e3),
        ]);
    }
    bs::row(&[
        "fifo".to_string(),
        "light-subset".to_string(),
        nlight.to_string(),
        "-".to_string(),
        "-".to_string(),
        format!("{:.1}", fifo_light / 1e3),
    ]);
    println!(
        "\nlight-tenant admission wait <= one in-flight turn per waiting light query \
         under flood, and its tail beats the FIFO schedule of the identical \
         workload ({:.1} vs {:.1} us) — asserted at runtime.",
        wfq_light / 1e3,
        fifo_light / 1e3
    );
}

/// Faults and degradation: the seeded fault plan against one captured
/// stage profile (depth 4, closed batch). Runtime contracts, asserted on
/// every run:
///
/// - a **zero-rate plan** (even with a nonzero seed) is structurally
///   inert — timeline, queueing and top-k bit-identical to the fault-free
///   schedule, availability reporting off;
/// - a **flaky-read plan** (40% far + SSD failures, bounded retries)
///   still serves every query with k results, surfacing retries and
///   degrade levels in the availability columns, deterministically
///   (re-scheduling reproduces the makespan bit-for-bit);
/// - a **1 ns deadline** degrades every query to its coarse fallback —
///   all k results, all deadlines reported missed;
/// - a **whole-run outage** of the only shard drops everything, and the
///   report says so.
fn faults_section(quick: bool) {
    println!("\n# Faults and degradation (seeded fault plan, degraded-mode serving)\n");
    let mut cfg = serving_config(quick);
    cfg.sim.shared_timeline = true;
    let dataset = synthesize(&cfg.dataset);
    let truth = ground_truth_for(&dataset, cfg.refine.k);
    let nq = dataset.num_queries();
    let k = cfg.refine.k;
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).expect("build"));
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);

    let (base_outs, base) = profile.schedule(4, 0.0);

    // --- zero-fault plan is structurally inert ---
    profile.set_fault(FaultConfig { seed: 0x5EED_FA17, ..Default::default() });
    profile.set_deadline_us(0.0);
    let (zero_outs, zero) = profile.schedule(4, 0.0);
    assert!(!zero.availability.active, "zero-rate plan must not activate fault accounting");
    assert_eq!(
        zero.makespan_ns, base.makespan_ns,
        "zero-fault makespan diverged from the fault-free schedule"
    );
    for q in 0..nq {
        assert_eq!(
            zero_outs[q].topk, base_outs[q].topk,
            "zero-fault top-k diverged from the fault-free schedule (query {q})"
        );
        assert_eq!(zero_outs[q].breakdown.queue_ns, base_outs[q].breakdown.queue_ns, "query {q}");
        assert_eq!(zero.timings[q].done_ns, base.timings[q].done_ns, "query {q}");
    }

    bs::header(&[
        "plan",
        "served",
        "success%",
        "degraded",
        "dropped",
        "retries",
        "ddl-miss",
        "recall@10",
        "makespan(us)",
    ]);
    let print_row = |name: &str, outs: &[fatrq::coordinator::QueryOutcome],
                     rep: &fatrq::coordinator::ServeReport| {
        let recall: f64 = outs
            .iter()
            .enumerate()
            .map(|(q, o)| recall_at_k(&o.topk, &truth[q], k))
            .sum::<f64>()
            / nq as f64;
        let av = &rep.availability;
        bs::row(&[
            name.to_string(),
            format!("{}/{}", av.served, av.queries),
            format!("{:.1}", av.success_rate() * 100.0),
            av.degraded.to_string(),
            av.dropped.to_string(),
            av.retries.to_string(),
            av.deadline_missed.to_string(),
            format!("{recall:.4}"),
            format!("{:.1}", rep.makespan_ns / 1e3),
        ]);
    };
    print_row("fault-free", &base_outs, &base);

    // --- flaky reads with bounded retries: every query still answers ---
    profile.set_fault(FaultConfig {
        seed: 42,
        far_fail_rate: 0.4,
        ssd_fail_rate: 0.4,
        retry_limit: 2,
        retry_backoff_us: 25.0,
        ..Default::default()
    });
    let (flaky_outs, flaky) = profile.schedule(4, 0.0);
    assert!(flaky.availability.active, "seeded plan must activate fault accounting");
    assert_eq!(flaky.availability.served, nq, "flaky reads must not drop queries");
    assert!(flaky.availability.retries > 0, "a 40% failure rate must surface retries");
    for (q, out) in flaky_outs.iter().enumerate() {
        assert_eq!(
            out.topk.len(),
            k,
            "query {q} degraded to {} but must still return k results",
            out.breakdown.degrade.name()
        );
    }
    let (_, again) = profile.schedule(4, 0.0);
    assert_eq!(
        flaky.makespan_ns, again.makespan_ns,
        "the seeded fault schedule must be reproducible"
    );
    print_row("flaky-reads", &flaky_outs, &flaky);

    // --- an impossible deadline degrades everything to the coarse path ---
    profile.set_fault(FaultConfig::default());
    profile.set_deadline_us(1e-3); // 1 ns: every query misses
    let (ddl_outs, ddl) = profile.schedule(4, 0.0);
    assert_eq!(ddl.availability.degraded, nq, "a 1 ns deadline must degrade every query");
    assert_eq!(ddl.availability.deadline_missed, nq);
    assert_eq!(ddl.availability.dropped, 0, "deadline misses degrade, never drop");
    for (q, out) in ddl_outs.iter().enumerate() {
        assert_eq!(out.topk.len(), k, "degraded query {q} must still return k results");
    }
    print_row("deadline-1ns", &ddl_outs, &ddl);

    // --- whole-run outage of the only shard: dropped and reported ---
    profile.set_deadline_us(0.0);
    profile.set_fault(FaultConfig {
        seed: 7,
        outages: vec![OutageSpec { shard: 0, start_us: 0.0, end_us: 1e12 }],
        ..Default::default()
    });
    let (out_outs, outage) = profile.schedule(4, 0.0);
    assert_eq!(outage.availability.dropped, nq, "a whole-run outage must drop every query");
    assert_eq!(outage.availability.served, 0);
    assert!(out_outs.iter().all(|o| o.topk.is_empty()), "dropped queries must return nothing");
    print_row("shard-outage", &out_outs, &outage);

    println!(
        "\nzero-rate plan bit-identical to fault-free, flaky reads retry to full answers, \
         deadline misses fall back to coarse k-results, outages drop and report — \
         asserted at runtime."
    );
}

/// Out-of-core serving: the cold PQ/IVF code structures paged behind an
/// SSD page cache (`cache.out_of_core`). One streaming build serves every
/// row (PQ training is not bit-reproducible across builds). Runtime
/// contracts, asserted on every run:
///
/// - the streaming build materializes no reconstruction matrix;
/// - a **warm cache** (`pages = 0`) is bit-identical to the same build
///   with its page tier detached — timeline, top-k and makespan;
/// - a **thrashing frame budget** misses, evicts, and queues page-in
///   bursts on the shard's shared SSD (`pagein-q > 0` under overlap),
///   while the top-k never changes — paging is a timing concern only;
/// - at **depth 1** the SSD is idle at every page-in: cold misses cost
///   service time but zero queue time.
fn outofcore_section(quick: bool) {
    println!("\n# Out-of-core serving (paged cold tier behind an SSD page cache)\n");
    let mut cfg = serving_config(quick);
    cfg.sim.shared_timeline = true;
    cfg.cache.out_of_core = true;
    cfg.cache.page_kb = 4;
    cfg.cache.pages = 0; // warm by default; swept below
    cfg.cache.pin_pages = 2;
    cfg.validate().expect("out-of-core config");
    let dataset = synthesize(&cfg.dataset);
    let truth = ground_truth_for(&dataset, cfg.refine.k);
    let nq = dataset.num_queries();
    let k = cfg.refine.k;
    let mut sys = build_system_with(&cfg, dataset.clone()).expect("build");
    assert!(sys.recon.is_empty(), "streaming build must not materialize the recon matrix");
    let total_pages = sys.paged.as_ref().expect("out-of-core build pages the cold tier").total_pages;

    // One serving pass, returning the system so the cache budget can be
    // swept over the single build.
    let run = |sys: fatrq::coordinator::BuiltSystem, pages: usize, depth: usize| {
        let mut sys = sys;
        sys.cfg.cache.pages = pages;
        let sys = Arc::new(sys);
        let (outs, rep) = {
            let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
            let profile = engine.profile_with(engine.params(), &dataset.queries);
            profile.schedule(depth, 0.0)
        };
        let sys = Arc::try_unwrap(sys).ok().expect("engine dropped: sole owner");
        (outs, rep, sys)
    };

    // In-memory reference: same build, page tier detached.
    let paged = sys.paged.take().unwrap();
    let (ref_outs, ref_rep, s) = run(sys, 0, 8);
    sys = s;
    assert!(!ref_rep.cache.active, "no page tier, no cache columns");
    sys.paged = Some(paged);

    bs::header(&[
        "cache(pages)",
        "hit%",
        "misses",
        "evictions",
        "pagein-q(us)",
        "mean(us)",
        "p99(us)",
        "makespan(us)",
        "recall@10",
    ]);
    let row = |label: String, outs: &[fatrq::coordinator::QueryOutcome],
               rep: &fatrq::coordinator::ServeReport| {
        let recall: f64 = outs
            .iter()
            .enumerate()
            .map(|(q, o)| recall_at_k(&o.topk, &truth[q], k))
            .sum::<f64>()
            / nq as f64;
        let c = &rep.cache;
        bs::row(&[
            label,
            format!("{:.1}", 100.0 * c.hit_rate()),
            c.misses.to_string(),
            c.evictions.to_string(),
            format!("{:.2}", rep.mean_pagein_queue_ns / 1e3),
            format!("{:.1}", rep.mean_latency_ns / 1e3),
            format!("{:.1}", rep.p99_ns / 1e3),
            format!("{:.1}", rep.makespan_ns / 1e3),
            format!("{recall:.4}"),
        ]);
    };

    // --- warm cache: bit-identical to in-memory ---
    let (warm_outs, warm_rep, s) = run(sys, 0, 8);
    sys = s;
    assert!(warm_rep.cache.active && warm_rep.cache.misses == 0, "pages=0 must be warm");
    assert_eq!(
        warm_rep.makespan_ns, ref_rep.makespan_ns,
        "warm out-of-core makespan diverged from the in-memory schedule"
    );
    for q in 0..nq {
        assert_eq!(
            warm_outs[q].topk, ref_outs[q].topk,
            "warm out-of-core top-k diverged from in-memory (query {q})"
        );
        assert_eq!(warm_rep.timings[q].done_ns, ref_rep.timings[q].done_ns, "query {q}");
    }
    row(format!("warm ({total_pages} resident)"), &warm_outs, &warm_rep);

    // --- thrashing budget: misses queue on the SSD, results unchanged ---
    let (solo_outs, solo_rep, s) = run(sys, 4, 1);
    sys = s;
    assert!(solo_rep.cache.misses > 0, "4 frames must miss");
    assert_eq!(
        solo_rep.mean_pagein_queue_ns, 0.0,
        "depth 1: page-ins land on an idle SSD, zero queue time"
    );
    row("4 @ depth 1".to_string(), &solo_outs, &solo_rep);

    let (cold_outs, cold_rep, _sys) = run(sys, 4, 8);
    let c = &cold_rep.cache;
    assert!(c.misses > 0 && c.evictions > 0 && c.hit_rate() < 1.0, "4 frames must thrash: {c:?}");
    assert!(
        cold_rep.mean_pagein_queue_ns > 0.0,
        "overlapping page-in bursts must queue on the shared SSD"
    );
    assert!(cold_rep.makespan_ns > warm_rep.makespan_ns, "paging must cost simulated time");
    for q in 0..nq {
        assert_eq!(
            cold_outs[q].topk, warm_outs[q].topk,
            "paging changed the top-k (query {q}) — it may only change timing"
        );
    }
    row("4 @ depth 8".to_string(), &cold_outs, &cold_rep);

    println!(
        "\nstreaming build holds no recon matrix, warm cache bit-identical to in-memory, \
         cold misses surface as SSD page-in queue time without touching the top-k — \
         asserted at runtime."
    );
}

/// CXL far-memory device pool: placement, hot-range replication and
/// per-query replica selection. Runtime-asserted contracts:
/// 1-device pool == single-timeline clock bit-for-bit under every
/// placement; total pool queueing strictly decreasing over 1 -> 2 -> 4
/// devices; under Zipfian query skew (s = 1.2, depth >= 4)
/// `replicate-hot` beats `interleave` at the tail (p99).
fn farpool_section(quick: bool) {
    println!("\n# CXL device pool (far tier as a pool of deterministic device timelines)\n");
    let mut cfg = serving_config(quick);
    cfg.sim.shared_timeline = true;
    // Small record ranges so the quick corpus spans many ranges and
    // interleaving across 4 devices is meaningful.
    cfg.far.range_kb = 1;
    cfg.validate().expect("pool config");
    let dataset = synthesize(&cfg.dataset);
    let nq = dataset.num_queries();
    let dim = dataset.dim;
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).expect("build"));
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);

    // --- contract: one device, any placement == today's clock ---
    let base = engine.profile_with(engine.params(), &dataset.queries);
    let (ref_outs, ref_rep) = base.schedule(4, 0.0);
    let mut one = engine.profile_with(engine.params(), &dataset.queries);
    one.set_far_devices(1);
    for placement in
        [FarPlacement::Interleave, FarPlacement::ShardAffine, FarPlacement::ReplicateHot]
    {
        one.set_far_placement(placement);
        let (outs, rep) = one.schedule(4, 0.0);
        assert_eq!(
            rep.makespan_ns, ref_rep.makespan_ns,
            "1-device pool under {placement:?} moved the clock"
        );
        assert!(!rep.farpool.active, "1-device pool must report inactive");
        for q in 0..nq {
            assert_eq!(outs[q].topk, ref_outs[q].topk, "{placement:?}: query {q} top-k");
            assert_eq!(
                rep.timings[q].done_ns, ref_rep.timings[q].done_ns,
                "{placement:?}: query {q} done"
            );
        }
    }

    bs::header(&["devices", "placement", "pool-q(us)", "balance", "p99(us)", "makespan(us)"]);
    let row = |devices: usize, placement: FarPlacement, rep: &fatrq::coordinator::ServeReport| {
        bs::row(&[
            devices.to_string(),
            placement.name().to_string(),
            format!("{:.1}", rep.farpool.total_queue_ns() / 1e3),
            format!("{:.2}", rep.farpool.balance()),
            format!("{:.1}", rep.p99_ns / 1e3),
            format!("{:.1}", rep.makespan_ns / 1e3),
        ]);
    };

    // --- device sweep: splitting fixed admissions over more devices ---
    // Depth 0 admits the whole batch at t = 0, so far admission instants
    // are pinned by the front-stage profiles and adding devices can only
    // relieve contention.
    let mut sweep = engine.profile_with(engine.params(), &dataset.queries);
    sweep.set_far_placement(FarPlacement::Interleave);
    let mut prev = f64::INFINITY;
    for devices in [1usize, 2, 4] {
        sweep.set_far_devices(devices);
        let (_, rep) = sweep.schedule(0, 0.0);
        let total = rep.farpool.total_queue_ns();
        assert!(
            total < prev,
            "pool queueing must strictly decrease with devices: {devices} devices \
             {total} ns !< {prev} ns"
        );
        row(devices, FarPlacement::Interleave, &rep);
        prev = total;
    }

    // --- Zipf-skewed tail: replicate-hot vs interleave ---
    // Duplicate query vectors by Zipf(s = 1.2) rank so a handful of
    // record streams (and so their leading ranges) dominate the far
    // tier. Interleave pins each hot range to one device; replicate-hot
    // spreads its admissions over the replica ring.
    let n_skew = if quick { 48 } else { 128 };
    let ranks = bs::gen_zipf_queries(91, n_skew, 1.2).expect("zipf ranks");
    let mut skewed = Vec::with_capacity(n_skew * dim);
    for &r in &ranks {
        let q = r % nq;
        skewed.extend_from_slice(&dataset.queries[q * dim..(q + 1) * dim]);
    }
    let mut pool = engine.profile_with(engine.params(), &skewed);
    pool.set_far_devices(4);
    pool.set_far_placement(FarPlacement::Interleave);
    let (_, rep_int) = pool.schedule(8, 0.0);
    row(4, FarPlacement::Interleave, &rep_int);
    pool.set_far_placement(FarPlacement::ReplicateHot);
    pool.set_far_replicas(2);
    pool.set_far_hot_alpha(0.5);
    let (_, rep_hot) = pool.schedule(8, 0.0);
    row(4, FarPlacement::ReplicateHot, &rep_hot);
    assert!(rep_hot.farpool.hot_ranges > 0, "skewed batch must surface hot ranges");
    assert!(
        rep_hot.p99_ns < rep_int.p99_ns,
        "replicate-hot must beat interleave at the tail under Zipf skew: p99 {} !< {}",
        rep_hot.p99_ns,
        rep_int.p99_ns
    );

    println!(
        "\n1-device pool bit-identical under every placement, pool queueing strictly \
         decreasing 1 -> 4 devices, replicate-hot under Zipf(s=1.2) skew beats interleave \
         at p99 — asserted at runtime."
    );
}
