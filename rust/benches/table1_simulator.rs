//! Table I — device-simulator validation.
//!
//! Confirms each simulator reproduces its configured Table I parameters:
//! DDR5-4800 34-34-34 timing, CXL 271 ns / 22 GB/s, SSD 45 µs / 1200K
//! IOPS — the numbers every pipeline latency in this repo is built on.

use fatrq::bench_support as bs;
use fatrq::config::SimConfig;
use fatrq::simulator::{CxlLink, DramSim, FarMemoryDevice, SsdSim};

fn main() {
    println!("# Table I — simulator validation\n");
    let cfg = SimConfig::default();
    bs::header(&["device", "metric", "configured", "measured", "ok"]);

    // --- DRAM ---
    let clock_ns = 1000.0 / cfg.dram_clock_mhz;
    let mut dram = DramSim::new(&cfg);
    let (done, _) = dram.read(0, 64, 0.0); // miss: tRCD + tCAS
    let miss_expect = (cfg.t_rcd + cfg.t_cas) as f64 * clock_ns;
    bs::row(&[
        "DDR5-4800".into(),
        "row-miss latency (ns)".into(),
        format!("{miss_expect:.1}+xfer"),
        format!("{done:.1}"),
        (done >= miss_expect && done < miss_expect + 10.0).to_string(),
    ]);
    let t0 = dram.now;
    let (done2, _) = dram.read(64, 64, t0); // hit: tCAS
    let hit_expect = cfg.t_cas as f64 * clock_ns;
    bs::row(&[
        "DDR5-4800".into(),
        "row-hit latency (ns)".into(),
        format!("{hit_expect:.1}+xfer"),
        format!("{:.1}", done2 - t0),
        (done2 - t0 >= hit_expect && done2 - t0 < hit_expect + 10.0).to_string(),
    ]);
    // Streaming bandwidth toward the peak.
    let mut dram2 = DramSim::new(&cfg);
    let elapsed = dram2.stream(0, 8192, 8192, 4096, 0.0);
    let gbps = (4096usize * 8192) as f64 / elapsed;
    bs::row(&[
        "DDR5-4800".into(),
        "stream bandwidth (GB/s)".into(),
        format!("<= {:.0} peak", dram2.peak_bandwidth_bpns()),
        format!("{gbps:.1}"),
        (gbps > 0.3 * dram2.peak_bandwidth_bpns() && gbps <= dram2.peak_bandwidth_bpns() * 1.01)
            .to_string(),
    ]);

    // --- CXL ---
    let link = CxlLink::new(&cfg);
    let idle = link.idle_latency_ns();
    bs::row(&[
        "CXL link".into(),
        "idle latency (ns)".into(),
        format!("{:.0}", cfg.cxl_latency_ns),
        format!("{idle:.1}"),
        ((idle - cfg.cxl_latency_ns).abs() < 15.0).to_string(),
    ]);
    let mut link2 = CxlLink::new(&cfg);
    let mut done = 0.0;
    for _ in 0..20_000 {
        done = link2.transfer(4096, 0.0);
    }
    let link_gbps = (20_000usize * 4096) as f64 / done;
    bs::row(&[
        "CXL link".into(),
        "sustained BW (GB/s)".into(),
        format!("{:.0}", cfg.cxl_bandwidth_gbps),
        format!("{link_gbps:.1}"),
        ((link_gbps - cfg.cxl_bandwidth_gbps).abs() < 1.0).to_string(),
    ]);

    // --- SSD ---
    let mut ssd = SsdSim::new(&cfg);
    let lat = ssd.read(3072, 0.0);
    bs::row(&[
        "NVMe SSD".into(),
        "read latency (us)".into(),
        format!("{:.0}", cfg.ssd_latency_us),
        format!("{:.1}", lat / 1e3),
        ((lat / 1e3 - cfg.ssd_latency_us).abs() < 1.0).to_string(),
    ]);
    let mut ssd2 = SsdSim::new(&cfg);
    let n = 200_000;
    let mut sdone = 0.0;
    for _ in 0..n {
        sdone = ssd2.read(4096, 0.0);
    }
    let kiops = n as f64 / (sdone / 1e9) / 1e3;
    bs::row(&[
        "NVMe SSD".into(),
        "sustained KIOPS".into(),
        format!("{:.0}", cfg.ssd_kiops),
        format!("{kiops:.0}"),
        ((kiops - cfg.ssd_kiops).abs() / cfg.ssd_kiops < 0.05).to_string(),
    ]);

    // --- Composed far-memory device: the tier ordering premise ---
    println!("\ntier latency ordering for one 162-B TRQ record:");
    let mut dev = FarMemoryDevice::new(&cfg);
    let local = dev.local_read(0, 162, 0.0);
    dev.reset();
    let host = dev.host_read(0, 162, 0.0);
    let ssd_one = SsdSim::new(&cfg).idle_latency_ns();
    bs::header(&["path", "latency (ns)"]);
    bs::row(&["on-device DRAM (HW mode)".into(), format!("{local:.0}")]);
    bs::row(&["host via CXL (SW mode)".into(), format!("{host:.0}")]);
    bs::row(&["SSD full-vector fetch".into(), format!("{ssd_one:.0}")]);
    assert!(local < host && host < ssd_one / 10.0);
    println!("\nordering holds: device < link < 0.1x SSD — the paper's tiering premise.");
}
