//! §V-E — accelerator overhead analysis.
//!
//! Paper claims: the CXL Type-2 refinement unit adds 0.729 mm² / 897 mW
//! (ASAP7 @ 1 GHz); the distance estimator is 29% area / 27% power, the
//! priority queues 6% / 8%; versus a 16-core Neoverse-V2 CXL memory
//! controller the overhead is under 1.8% area and 4% power.

use fatrq::accel::{AccelCostModel, ComponentCost};
use fatrq::bench_support as bs;

fn pct(x: f64, total: f64) -> String {
    format!("{:.1}%", 100.0 * x / total)
}

fn main() {
    println!("# §V-E — accelerator area/power overhead\n");
    let m = AccelCostModel::default();
    let total = m.total();
    let est = m.estimator();
    let q = m.queues();
    let infra = m.infrastructure();

    bs::header(&["component", "area (mm²)", "area %", "power (mW)", "power %"]);
    for (name, c) in [
        ("distance estimator", est),
        ("priority queues (2x1024)", q),
        ("decode LUT / buffers / CXL ctrl", infra),
        ("TOTAL", total),
    ] {
        bs::row(&[
            name.to_string(),
            format!("{:.3}", c.area_mm2),
            pct(c.area_mm2, total.area_mm2),
            format!("{:.0}", c.power_mw),
            pct(c.power_mw, total.power_mw),
        ]);
    }

    println!("\npaper: total 0.729 mm² / 897 mW; estimator 29%/27%; queues 6%/8%.");

    let (area_frac, power_frac) = m.overhead_vs_controller(16);
    println!(
        "\nvs 16x Neoverse-V2 controller (2.5 mm² / 1.4 W per core):\n  area overhead  {:.2}%  (paper: <1.8%)\n  power overhead {:.2}%  (paper: 4%)",
        area_frac * 100.0,
        power_frac * 100.0
    );

    // Scaling study: how the overhead moves with the design knobs.
    println!("\nscaling (queue entries x decode lanes):");
    bs::header(&["queues", "lanes", "area (mm²)", "power (mW)"]);
    for entries in [256usize, 512, 1024] {
        for lanes in [4usize, 8, 16] {
            let c = AccelCostModel { queue_entries: entries, decode_lanes: lanes, mac_width: 5 };
            let ComponentCost { area_mm2, power_mw } = c.total();
            bs::row(&[
                entries.to_string(),
                lanes.to_string(),
                format!("{area_mm2:.3}"),
                format!("{power_mw:.0}"),
            ]);
        }
    }
}
