//! Hot-path microbenchmarks — the §Perf evidence base (EXPERIMENTS.md).
//!
//! Measures the operations the pipeline executes per candidate/query:
//! ternary encode, packed qdot (byte-LUT vs per-query ADC table), blocked
//! vs per-id ADC scans, allocation-free vs allocating front stage, full
//! refinement, engine cycle throughput. Wall-clock medians over repeated
//! runs.
//!
//! `--quick` runs a reduced-iteration smoke pass (the CI kernel-regression
//! canary); numbers are noisier but every kernel row still prints.

use fatrq::accel::RefineEngine;
use fatrq::bench_support::simd_ab;
use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, Pipeline, QueryEngine};
use fatrq::index::{AnnIndex, IndexScratch};
use fatrq::kernels::pqscan::{adc_scan_topk, l2_scan_topk};
use fatrq::kernels::ternary::{qdot_packed_tab, TernaryQueryLut};
use fatrq::kernels::{detected_tier, SimdTier};
use fatrq::quant::pack::{pack_ternary, packed_len, unpack_ternary};
use fatrq::quant::trq::{qdot_packed, ternary_encode, TrqStore};
use fatrq::quant::ProductQuantizer;
use fatrq::refine::{Calibration, ProgressiveEstimator};
use fatrq::util::rng::Rng;
use fatrq::util::topk::{Scored, TopK};
use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

fn time_median<F: FnMut()>(mut f: F, iters: usize, reps: usize) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[reps / 2]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let reps = if quick { 3 } else { 7 };
    let scale = if quick { 10 } else { 1 }; // divide iteration counts
    println!(
        "# hot-path microbenchmarks (ns/op, median of {reps} reps{})\n",
        if quick { ", --quick smoke mode" } else { "" }
    );
    let mut rng = Rng::new(123);
    let dim = 768usize;

    // Fixtures.
    let delta: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32() * 0.1).collect();
    let query: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
    let code = ternary_encode(&delta);
    let mut packed = vec![0u8; packed_len(dim)];
    pack_ternary(&code.trits, &mut packed);

    println!("| op | ns/op | notes |");
    println!("|---|---|---|");

    let t = time_median(|| { black_box(ternary_encode(black_box(&delta))); }, 200 / scale, reps);
    println!("| ternary_encode (768-D) | {t:.0} | O(D log D) encode, offline path |");

    let qdot_lut_ns = time_median(
        || {
            black_box(qdot_packed(black_box(&query), black_box(&packed), dim));
        },
        (2000 / scale).max(1),
        reps,
    );
    println!("| qdot_packed byte-LUT (768-D, 154 B) | {qdot_lut_ns:.0} | fallback kernel, 5 FMA/byte |");

    // --- tentpole kernel 1: per-query ternary ADC table ---
    // A realistic candidate batch so the table sees many distinct codes,
    // not one L1-pinned row.
    let batch: Vec<Vec<u8>> = (0..512)
        .map(|i| {
            let mut r = Rng::new(900 + i as u64);
            let d: Vec<f32> = (0..dim).map(|_| r.gaussian_f32()).collect();
            let c = ternary_encode(&d);
            let mut p = vec![0u8; packed_len(dim)];
            pack_ternary(&c.trits, &mut p);
            p
        })
        .collect();
    let mut tab = TernaryQueryLut::new();
    let tab_build_ns = time_median(|| tab.build(black_box(&query)), (200 / scale).max(1), reps);
    let qdot_tab_ns = time_median(
        || {
            black_box(qdot_packed_tab(black_box(&tab), black_box(&packed)));
        },
        (2000 / scale).max(1),
        reps,
    );
    let lut_batch_ns = time_median(
        || {
            let mut acc = 0.0f32;
            for p in &batch {
                acc += qdot_packed(black_box(&query), p, dim).0;
            }
            black_box(acc);
        },
        (20 / scale).max(1),
        reps,
    ) / batch.len() as f64;
    let tab_batch_ns = time_median(
        || {
            let mut acc = 0.0f32;
            for p in &batch {
                acc += qdot_packed_tab(black_box(&tab), p).0;
            }
            black_box(acc);
        },
        (20 / scale).max(1),
        reps,
    ) / batch.len() as f64;
    println!("| ternary ADC-table build (154x243) | {tab_build_ns:.0} | once per query, base-3 DP |");
    println!("| qdot_packed table kernel (768-D) | {qdot_tab_ns:.0} | 1 lookup+add/byte, hot code |");
    println!(
        "| qdot over 512-code batch: byte-LUT | {lut_batch_ns:.0} | per candidate, streaming codes |"
    );
    println!(
        "| qdot over 512-code batch: table | {tab_batch_ns:.0} | per candidate, streaming codes |"
    );

    let t = time_median(
        || {
            let mut out = vec![0i8; dim];
            unpack_ternary(black_box(&packed), dim, &mut out);
            black_box(out);
        },
        (1000 / scale).max(1),
        reps,
    );
    println!("| unpack_ternary (768-D) | {t:.0} | decode-LUT equivalent |");

    // ADC scoring.
    let n = 4000usize;
    let mut data = vec![0f32; n * dim];
    rng.fill_gaussian(&mut data);
    let pq = ProductQuantizer::train(&data[..500 * dim], dim, 96, 8, 4, 0, 9);
    let codes = pq.encode(&data[..500 * dim]);
    let lut = pq.adc_table(&query);
    let t = time_median(
        || {
            let mut acc = 0f32;
            for i in 0..500 {
                acc += pq.adc_distance(black_box(&lut), &codes[i * 96..(i + 1) * 96]);
            }
            black_box(acc);
        },
        (20 / scale).max(1),
        reps,
    ) / 500.0;
    println!("| pq_adc_distance (96 subq) | {t:.0} | per-candidate coarse score |");

    let t = time_median(|| { black_box(pq.adc_table(black_box(&query))); }, (50 / scale).max(1), reps);
    println!("| adc_table build (96x256) | {t:.0} | once per query |");

    // Full refinement of a 320-candidate list (the §V-B depth).
    let n_small = 2000usize;
    let small: Vec<f32> = data[..n_small * dim].to_vec();
    let mut recon = vec![0f32; n_small * dim];
    let codes2 = pq.encode(&small);
    for i in 0..n_small {
        pq.decode_one(&codes2[i * 96..(i + 1) * 96], &mut recon[i * dim..(i + 1) * dim]);
    }
    let store = TrqStore::build(&small, &recon, dim);

    // --- tentpole kernel 2: blocked ADC scan over contiguous rows ---
    // The old IVF path gathers codes at scattered ids through per-id
    // `QueryScorer::score` calls; the blocked path scans list-contiguous
    // rows (the `list_codes` layout) feeding a TopK. Same work, different
    // memory shape — this is the IVF front-stage transformation.
    let scan_n = 500usize;
    let mut scattered_ids: Vec<usize> = (0..n_small).collect();
    rng.shuffle(&mut scattered_ids);
    scattered_ids.truncate(scan_n);
    let list_ids: Vec<u32> = scattered_ids.iter().map(|&i| i as u32).collect();
    let mut list_rows = Vec::with_capacity(scan_n * 96);
    for &i in &scattered_ids {
        list_rows.extend_from_slice(&codes2[i * 96..(i + 1) * 96]);
    }
    let mut dist_scratch: Vec<f32> = Vec::new();
    let mut top_scratch = TopK::new(100);
    let per_id_ns = time_median(
        || {
            top_scratch.reset(100);
            for &i in &scattered_ids {
                top_scratch.push(
                    pq.adc_distance(black_box(&lut), &codes2[i * 96..(i + 1) * 96]),
                    i as u64,
                );
            }
            black_box(top_scratch.len());
        },
        (20 / scale).max(1),
        reps,
    ) / scan_n as f64;
    let blocked_ns = time_median(
        || {
            top_scratch.reset(100);
            adc_scan_topk(
                black_box(&lut),
                pq.ksub,
                pq.m,
                black_box(&list_rows),
                &list_ids,
                &mut dist_scratch,
                &mut top_scratch,
            );
            black_box(top_scratch.len());
        },
        (20 / scale).max(1),
        reps,
    ) / scan_n as f64;
    println!("| IVF scan per-id gather + top-k (96 subq) | {per_id_ns:.0} | old front-stage inner loop |");
    println!("| IVF blocked scan + top-k (96 subq) | {blocked_ns:.0} | contiguous list_codes rows |");

    // --- SIMD dispatch tiers: scalar reference vs runtime-dispatched ---
    // Each hot kernel timed as dispatched, then with the scalar tier
    // pinned (`force_scalar_scope`). The tiers are bit-identical, so the
    // only thing allowed to differ is time: on AVX2 the ratio rows are
    // runtime-asserted to never regress below the scalar reference. On a
    // scalar-only process both runs take the same path (ratio ~1, no
    // assert).
    let tier = detected_tier();
    println!("\n# SIMD dispatch (detected tier: {})\n", tier.name());
    println!("| kernel | scalar ns | dispatched ns | ratio |");
    println!("|---|---|---|---|");
    let (adc_s, adc_d) = simd_ab(
        || {
            top_scratch.reset(100);
            adc_scan_topk(
                black_box(&lut),
                pq.ksub,
                pq.m,
                black_box(&list_rows),
                &list_ids,
                &mut dist_scratch,
                &mut top_scratch,
            );
            black_box(top_scratch.len());
        },
        (20 / scale).max(1),
        reps,
    );
    let l2_rows = &small[..scan_n * dim];
    let (l2_s, l2_d) = simd_ab(
        || {
            top_scratch.reset(100);
            l2_scan_topk(black_box(&query), black_box(l2_rows), dim, &mut dist_scratch, &mut top_scratch);
            black_box(top_scratch.len());
        },
        (20 / scale).max(1),
        reps,
    );
    let (tern_s, tern_d) = simd_ab(
        || {
            let mut acc = 0.0f32;
            let mut live = 0usize;
            for p in &batch {
                let (d, k) = qdot_packed_tab(black_box(&tab), p);
                acc += d;
                live += k;
            }
            black_box((acc, live));
        },
        (20 / scale).max(1),
        reps,
    );
    // Encode-side distance helper: `util::l2_sq` delegates into the
    // dispatched scan-row kernel, so k-means/TRQ-encode/ground-truth
    // loops ride the same tier — this row pins the delegation's win.
    let (l2sq_s, l2sq_d) = simd_ab(
        || {
            let mut acc = 0.0f32;
            for r in l2_rows.chunks_exact(dim) {
                acc += fatrq::util::l2_sq(black_box(&query), r);
            }
            black_box(acc);
        },
        (20 / scale).max(1),
        reps,
    );
    for (name, s, d) in [
        ("adc_scan_topk (500x96 codes)", adc_s, adc_d),
        ("l2_scan_topk (500x768 f32)", l2_s, l2_d),
        ("qdot_packed_tab (512x154 B)", tern_s, tern_d),
        ("util::l2_sq encode-side (500x768 f32)", l2sq_s, l2sq_d),
    ] {
        let ratio = s / d.max(1e-9);
        println!("| {name} | {s:.0} | {d:.0} | {ratio:.2}x |");
        if tier == SimdTier::Avx2 {
            assert!(
                ratio >= 1.0,
                "{name}: dispatched AVX2 slower than pinned scalar ({ratio:.2}x)"
            );
        }
    }

    let est = ProgressiveEstimator::new(&store, Calibration::analytic());
    let cands: Vec<Scored> = (0..320)
        .map(|i| Scored::new(i as f32, (i * 5 % n_small) as u64))
        .collect();
    let mut refined = Vec::new();
    let refine_lut_ns = time_median(
        || {
            est.refine_into(black_box(&query), black_box(&cands), &mut refined);
            black_box(&refined);
        },
        (50 / scale).max(1),
        reps,
    );
    let refine_tab_ns = time_median(
        || {
            tab.build(black_box(&query));
            est.refine_into_with(black_box(&query), black_box(&cands), &mut refined, Some(&tab));
            black_box(&refined);
        },
        (50 / scale).max(1),
        reps,
    );
    println!("| refine 320 cands, byte-LUT (768-D) | {refine_lut_ns:.0} | SW-mode per-query refinement |");
    println!("| refine 320 cands, table kernel (768-D) | {refine_tab_ns:.0} | incl. per-query table build |");

    // HW engine: cycles + functional.
    let engine = RefineEngine::new(&store, Calibration::analytic());
    let (_, timing) = engine.refine(&query, &cands, 320);
    println!(
        "| HW engine refine (320 cands) | {} cycles = {:.0} ns @1 GHz | device model |",
        timing.cycles, timing.ns
    );

    let t = time_median(
        || {
            let mut out = vec![0u8; packed_len(dim)];
            pack_ternary(black_box(&code.trits), &mut out);
            black_box(out);
        },
        (1000 / scale).max(1),
        reps,
    );
    println!("| pack_ternary (768-D) | {t:.0} | offline encode path |");

    // Throughput summary: the acceptance metric is single-candidate hot-
    // code throughput (table path vs byte-LUT baseline) plus the streaming
    // batch as the honest cache-pressure number.
    println!(
        "\nternary-dot single-code speedup (table vs byte-LUT): {:.2}x ({:.0} -> {:.0} ns)",
        qdot_lut_ns / qdot_tab_ns.max(1e-9),
        qdot_lut_ns,
        qdot_tab_ns
    );
    println!(
        "ternary-dot 512-code-batch speedup (table vs byte-LUT): {:.2}x ({:.0} -> {:.0} ns)",
        lut_batch_ns / tab_batch_ns.max(1e-9),
        lut_batch_ns,
        tab_batch_ns
    );
    println!(
        "table build amortizes after ~{:.0} candidates",
        tab_build_ns / (lut_batch_ns - tab_batch_ns).max(1e-9)
    );
    println!(
        "blocked ADC scan speedup vs per-id: {:.2}x ({:.0} -> {:.0} ns/cand)",
        per_id_ns / blocked_ns.max(1e-9),
        per_id_ns,
        blocked_ns
    );
    println!(
        "SW refinement throughput: {:.1} M candidates/s/core ({:.0} ns each, table kernel)",
        1e3 / (refine_tab_ns / 320.0),
        refine_tab_ns / 320.0
    );
    println!(
        "HW engine throughput: {:.1} M candidates/s ({} cycles/cand @1 GHz)",
        1e3 / (timing.ns / 320.0),
        timing.cycles / 320
    );

    // --- scratch-reusing engine vs the old per-query-allocation path ---
    // Pipeline::query rebuilds SsdSim/FarMemoryDevice (2k+ bank states) and
    // all working buffers on every call; the persistent engine resets one
    // per-worker scratch instead. Same functional path, same mode.
    println!("\n# serving path: per-query allocation vs reused scratch\n");
    let cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 64,
            count: 4000,
            clusters: 32,
            noise: 0.35,
            query_noise: 1.0,
            queries: 32,
            seed: 12,
        },
        quant: QuantConfig { pq_m: 16, pq_nbits: 6, kmeans_iters: 6, train_sample: 2048 },
        index: IndexConfig { kind: IndexKind::Ivf, nlist: 48, nprobe: 12, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqSw,
            candidates: 100,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.01,
            ..Default::default()
        },
        ..Default::default()
    };
    let sys = Arc::new(build_system(&cfg).expect("microbench system"));
    let nq = sys.dataset.num_queries();
    let pipeline = Pipeline::new(&sys);
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let mut scratch = engine.scratch();
    let serve_reps = if quick { 3 } else { 9 };

    let legacy_ns = time_median(
        || {
            for q in 0..nq {
                black_box(pipeline.query(sys.dataset.query(q)));
            }
        },
        1,
        serve_reps,
    ) / nq as f64;
    let reused_ns = time_median(
        || {
            for q in 0..nq {
                black_box(engine.query_with_scratch(sys.dataset.query(q), &mut scratch));
            }
        },
        1,
        serve_reps,
    ) / nq as f64;

    // --- tentpole kernel 3: zero-allocation front stage ---
    let ann = sys.index.as_ann();
    let mut idx_scratch = IndexScratch::new();
    let mut front_out = Vec::new();
    let search_alloc_ns = time_median(
        || {
            for q in 0..nq {
                black_box(ann.search(sys.dataset.query(q), 100));
            }
        },
        1,
        serve_reps,
    ) / nq as f64;
    let search_into_ns = time_median(
        || {
            for q in 0..nq {
                ann.search_into(sys.dataset.query(q), 100, &mut idx_scratch, &mut front_out);
                black_box(&front_out);
            }
        },
        1,
        serve_reps,
    ) / nq as f64;

    println!("| path | ns/query | notes |");
    println!("|---|---|---|");
    println!("| front stage `search` (fresh scratch) | {search_alloc_ns:.0} | allocating wrapper |");
    println!("| front stage `search_into` (reused) | {search_into_ns:.0} | blocked scan + scratch reuse |");
    println!("| Pipeline::query (fresh scratch/query) | {legacy_ns:.0} | old serving path |");
    println!("| QueryEngine scratch reuse | {reused_ns:.0} | persistent engine hot path |");
    println!(
        "\nfront-stage search_into speedup: {:.2}x",
        search_alloc_ns / search_into_ns.max(1e-9)
    );
    println!(
        "scratch reuse speedup on the refine/serve path: {:.2}x",
        legacy_ns / reused_ns.max(1e-9)
    );
}
