//! Hot-path microbenchmarks — the §Perf evidence base (EXPERIMENTS.md).
//!
//! Measures the operations the pipeline executes per candidate/query:
//! ternary encode, packed qdot, ADC scoring, full refinement, engine
//! cycle throughput. Wall-clock medians over repeated runs.

use fatrq::accel::RefineEngine;
use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, Pipeline, QueryEngine};
use fatrq::quant::pack::{pack_ternary, packed_len, unpack_ternary};
use fatrq::quant::trq::{qdot_packed, ternary_encode, TrqStore};
use fatrq::quant::ProductQuantizer;
use fatrq::refine::{Calibration, ProgressiveEstimator};
use fatrq::util::rng::Rng;
use fatrq::util::topk::Scored;
use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

fn time_median<F: FnMut()>(mut f: F, iters: usize, reps: usize) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        samples.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[reps / 2]
}

fn main() {
    println!("# hot-path microbenchmarks (ns/op, median of 7 reps)\n");
    let mut rng = Rng::new(123);
    let dim = 768usize;

    // Fixtures.
    let delta: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32() * 0.1).collect();
    let query: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
    let code = ternary_encode(&delta);
    let mut packed = vec![0u8; packed_len(dim)];
    pack_ternary(&code.trits, &mut packed);

    println!("| op | ns/op | notes |");
    println!("|---|---|---|");

    let t = time_median(|| { black_box(ternary_encode(black_box(&delta))); }, 200, 7);
    println!("| ternary_encode (768-D) | {t:.0} | O(D log D) encode, offline path |");

    let t = time_median(
        || {
            black_box(qdot_packed(black_box(&query), black_box(&packed), dim));
        },
        2000,
        7,
    );
    println!("| qdot_packed (768-D, 154 B) | {t:.0} | per-candidate refinement core |");

    let t = time_median(
        || {
            let mut out = vec![0i8; dim];
            unpack_ternary(black_box(&packed), dim, &mut out);
            black_box(out);
        },
        1000,
        7,
    );
    println!("| unpack_ternary (768-D) | {t:.0} | decode-LUT equivalent |");

    // ADC scoring.
    let n = 4000usize;
    let mut data = vec![0f32; n * dim];
    rng.fill_gaussian(&mut data);
    let pq = ProductQuantizer::train(&data[..500 * dim], dim, 96, 8, 4, 0, 9);
    let codes = pq.encode(&data[..500 * dim]);
    let lut = pq.adc_table(&query);
    let t = time_median(
        || {
            let mut acc = 0f32;
            for i in 0..500 {
                acc += pq.adc_distance(black_box(&lut), &codes[i * 96..(i + 1) * 96]);
            }
            black_box(acc);
        },
        20,
        7,
    );
    println!("| pq_adc_distance (96 subq) | {:.0} | per-candidate coarse score |", t / 500.0);

    let t = time_median(|| { black_box(pq.adc_table(black_box(&query))); }, 50, 7);
    println!("| adc_table build (96x256) | {t:.0} | once per query |");

    // Full refinement of a 320-candidate list (the §V-B depth).
    let n_small = 2000usize;
    let small: Vec<f32> = data[..n_small * dim].to_vec();
    let mut recon = vec![0f32; n_small * dim];
    let codes2 = pq.encode(&small);
    for i in 0..n_small {
        pq.decode_one(&codes2[i * 96..(i + 1) * 96], &mut recon[i * dim..(i + 1) * dim]);
    }
    let store = TrqStore::build(&small, &recon, dim);
    let est = ProgressiveEstimator::new(&store, Calibration::analytic());
    let cands: Vec<Scored> = (0..320)
        .map(|i| Scored::new(i as f32, (i * 5 % n_small) as u64))
        .collect();
    let t = time_median(|| { black_box(est.refine_list(black_box(&query), black_box(&cands))); }, 50, 7);
    println!("| refine_list (320 cands, 768-D) | {t:.0} | SW-mode per-query refinement |");

    // HW engine: cycles + functional.
    let engine = RefineEngine::new(&store, Calibration::analytic());
    let (_, timing) = engine.refine(&query, &cands, 320);
    println!(
        "| HW engine refine (320 cands) | {} cycles = {:.0} ns @1 GHz | device model |",
        timing.cycles, timing.ns
    );

    let t = time_median(
        || {
            let mut out = vec![0u8; packed_len(dim)];
            pack_ternary(black_box(&code.trits), &mut out);
            black_box(out);
        },
        1000,
        7,
    );
    println!("| pack_ternary (768-D) | {t:.0} | offline encode path |");

    // Throughput summary.
    let qdot_ns = time_median(
        || {
            black_box(qdot_packed(black_box(&query), black_box(&packed), dim));
        },
        2000,
        7,
    );
    println!(
        "\nSW refinement throughput: {:.1} M candidates/s/core ({:.0} ns each)",
        1e3 / qdot_ns,
        qdot_ns
    );
    println!(
        "HW engine throughput: {:.1} M candidates/s ({} cycles/cand @1 GHz)",
        1e3 / (timing.ns / 320.0),
        timing.cycles / 320
    );

    // --- scratch-reusing engine vs the old per-query-allocation path ---
    // Pipeline::query rebuilds SsdSim/FarMemoryDevice (2k+ bank states) and
    // all working buffers on every call; the persistent engine resets one
    // per-worker scratch instead. Same functional path, same mode.
    println!("\n# serving path: per-query allocation vs reused scratch\n");
    let cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 64,
            count: 4000,
            clusters: 32,
            noise: 0.35,
            query_noise: 1.0,
            queries: 32,
            seed: 12,
        },
        quant: QuantConfig { pq_m: 16, pq_nbits: 6, kmeans_iters: 6, train_sample: 2048 },
        index: IndexConfig { kind: IndexKind::Ivf, nlist: 48, nprobe: 12, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqSw,
            candidates: 100,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.01,
            ..Default::default()
        },
        ..Default::default()
    };
    let sys = Arc::new(build_system(&cfg).expect("microbench system"));
    let nq = sys.dataset.num_queries();
    let pipeline = Pipeline::new(&sys);
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let mut scratch = engine.scratch();

    let legacy_ns = time_median(
        || {
            for q in 0..nq {
                black_box(pipeline.query(sys.dataset.query(q)));
            }
        },
        1,
        9,
    ) / nq as f64;
    let reused_ns = time_median(
        || {
            for q in 0..nq {
                black_box(engine.query_with_scratch(sys.dataset.query(q), &mut scratch));
            }
        },
        1,
        9,
    ) / nq as f64;
    println!("| path | ns/query | notes |");
    println!("|---|---|---|");
    println!("| Pipeline::query (fresh scratch/query) | {legacy_ns:.0} | old serving path |");
    println!("| QueryEngine scratch reuse | {reused_ns:.0} | persistent engine hot path |");
    println!(
        "\nscratch reuse speedup on the refine/serve path: {:.2}x",
        legacy_ns / reused_ns.max(1e-9)
    );
}
