//! §V-C storage-efficiency table.
//!
//! Paper claim: a 768-D FaTRQ record needs 768/5 + 8 = 162 bytes (five
//! ternary values per byte + two f32 scalars) versus 768*4/8 = 384 bytes
//! for 4-bit SQ at comparable MSE — 2.4x better storage efficiency.

use fatrq::bench_support as bs;
use fatrq::quant::pack::{bits_per_dim, packed_len};
use fatrq::quant::ScalarQuantizer;

fn main() {
    println!("# §V-C — far-memory storage cost per record\n");
    bs::header(&["format", "768-D bytes", "bits/dim", "vs FaTRQ"]);
    let fatrq_bytes = packed_len(768) + 8;
    let rows: Vec<(&str, usize)> = vec![
        ("full precision f32", 768 * 4),
        ("INT8 (w/o RQ)", 768),
        ("4-bit SQ residual", ScalarQuantizer::new(4).record_bytes(768) - 8), // paper counts code bytes
        ("3-bit SQ residual", ScalarQuantizer::new(3).record_bytes(768) - 8),
        ("FaTRQ ternary (ours)", fatrq_bytes),
    ];
    for (name, bytes) in rows {
        bs::row(&[
            name.to_string(),
            bytes.to_string(),
            format!("{:.2}", bytes as f64 * 8.0 / 768.0),
            format!("{:.2}x", bytes as f64 / fatrq_bytes as f64),
        ]);
    }
    println!();
    println!("FaTRQ record layout: {} packed bytes + 8 scalar bytes = {} B", packed_len(768), fatrq_bytes);
    println!("packing efficiency: {:.3} bits/dim vs log2(3) = 1.585 entropy bound", bits_per_dim(768));
    println!(
        "storage efficiency vs 4-bit SQ: {:.2}x (paper: 384/162 = 2.4x)",
        384.0 / fatrq_bytes as f64
    );

    // Corpus-scale view (the capacity argument of §I).
    println!("\ncorpus-scale far-memory footprint (88M records, Wiki-scale):");
    bs::header(&["format", "footprint (GB)"]);
    for (name, bytes) in [("4-bit SQ", 384usize), ("FaTRQ", fatrq_bytes)] {
        bs::row(&[
            name.to_string(),
            format!("{:.1}", 88e6 * bytes as f64 / 1e9),
        ]);
    }
}
