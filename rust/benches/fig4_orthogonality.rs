//! Fig 4 — Residual/query-offset orthogonality.
//!
//! Paper claim: after coarse quantization, the residual direction e_δ is
//! nearly orthogonal to the query offset e_{q−x_c}, so their inner product
//! is small and concentrated around zero — the property that makes the
//! first-order approximation usable and the TRQ estimator unbiased.

use fatrq::bench_support as bs;
use fatrq::config::IndexKind;
use fatrq::util::{dot, norm};

fn main() {
    println!("# Fig 4 — cos(e_q-xc, e_delta) distribution\n");
    let dataset = bs::bench_dataset();
    let sys = bs::build_bench_system(IndexKind::Ivf, dataset);
    let dim = sys.dataset.dim;

    // For each query, its top candidates' residual/offset cosines.
    // The query's own seed vector (queries are perturbed database draws)
    // is excluded: there q − x_c ≈ δ by construction, so cos ≈ 1 — a
    // degenerate pair that does not exist in the paper's setup.
    let mut cosines = Vec::new();
    for q in 0..sys.dataset.num_queries() {
        let query = sys.dataset.query(q);
        for cand in sys.index.as_ann().search(query, 50) {
            let id = cand.id as usize;
            let x = sys.dataset.vector(id);
            if fatrq::util::l2_sq(query, x) < 1e-3 {
                continue; // seed-identical pair
            }
            let xc = &sys.recon[id * dim..(id + 1) * dim];
            let offset: Vec<f32> = query.iter().zip(xc).map(|(a, b)| a - b).collect();
            let delta: Vec<f32> = x.iter().zip(xc).map(|(a, b)| a - b).collect();
            let (no, nd) = (norm(&offset), norm(&delta));
            if no > 1e-9 && nd > 1e-9 {
                cosines.push((dot(&offset, &delta) / (no * nd)) as f64);
            }
        }
    }

    let n = cosines.len() as f64;
    let mean = cosines.iter().sum::<f64>() / n;
    let var = cosines.iter().map(|c| (c - mean).powi(2)).sum::<f64>() / n;
    println!("pairs: {}", cosines.len());
    println!("mean cos : {mean:+.4}   (paper: ~0, residual ⟂ offset)");
    println!("std  cos : {:.4}   (isotropic {dim}-D reference: {:.4})", var.sqrt(), (1.0 / dim as f64).sqrt());

    // Histogram.
    println!("\nhistogram of cos values:");
    let bins = 21;
    let mut hist = vec![0usize; bins];
    for &c in &cosines {
        let idx = (((c + 1.0) / 2.0) * (bins as f64 - 1.0)).round() as usize;
        hist[idx.min(bins - 1)] += 1;
    }
    let max = *hist.iter().max().unwrap_or(&1);
    for (i, &h) in hist.iter().enumerate() {
        let center = -1.0 + 2.0 * i as f64 / (bins as f64 - 1.0);
        let bar = "#".repeat(h * 50 / max.max(1));
        println!("{center:+.2} {bar} {h}");
    }

    // The quantitative check the estimator relies on: concentration near
    // zero. A small positive mean remains on normalized synthetic
    // embeddings (PQ reconstructions sit slightly inside the unit sphere,
    // so both q−x_c and δ point radially outward); the OLS calibration
    // absorbs exactly this kind of systematic bias (§III-E).
    let within = cosines.iter().filter(|c| c.abs() < 0.3).count() as f64 / n;
    println!("\nfraction with |cos| < 0.3: {within:.3} (concentration near zero)");
    assert!(
        mean.abs() < 0.25 && within > 0.7,
        "offset/residual strongly correlated: mean {mean:.3}, within {within:.3}"
    );
}
