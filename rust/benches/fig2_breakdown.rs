//! Fig 2 — Runtime breakdown of the IVF-refinement ANNS baseline.
//!
//! Paper claim: with full-precision vectors on SSD, second-pass refinement
//! dominates query time (>90% at deep candidate lists); index traversal is
//! only 2–15% thanks to GPU acceleration; an all-in-DRAM system would be
//! up to 14x faster (the unattainable upper bound motivating FaTRQ).

use fatrq::bench_support as bs;
use fatrq::config::{IndexKind, RefineMode, SystemConfig};
use fatrq::coordinator::Pipeline;
use fatrq::simulator::SsdSim;

fn main() {
    println!("# Fig 2 — runtime breakdown, IVF + SSD-refinement baseline\n");
    let dataset = bs::bench_dataset();
    let sys = bs::build_bench_system(IndexKind::Ivf, dataset);
    let cfg: &SystemConfig = &sys.cfg;

    bs::header(&[
        "candidates",
        "traversal %",
        "ssd io %",
        "distance %",
        "total (us)",
        "dram-bound speedup",
    ]);
    for cands in [100usize, 200, 320, 640] {
        let mut p = Pipeline::new(&sys).with_mode(RefineMode::Baseline);
        p.candidates = cands;
        let nq = sys.dataset.num_queries();
        let mut trav = 0.0;
        let mut ssd = 0.0;
        let mut dist = 0.0;
        for q in 0..nq {
            let out = p.query(sys.dataset.query(q));
            trav += out.breakdown.traversal_ns;
            ssd += out.breakdown.ssd_ns;
            dist += out.breakdown.rerank_ns + out.breakdown.refine_compute_ns;
        }
        let total = trav + ssd + dist;
        // Hypothetical: vectors in host DRAM instead of SSD.
        let host_dram_ns = cands as f64
            * (cfg.sim.host_dram_latency_ns
                + (sys.dataset.dim * 4) as f64 / cfg.sim.host_dram_bandwidth_gbps);
        let dram_total = trav + host_dram_ns * nq as f64 + dist;
        bs::row(&[
            cands.to_string(),
            format!("{:.1}", 100.0 * trav / total),
            format!("{:.1}", 100.0 * ssd / total),
            format!("{:.1}", 100.0 * dist / total),
            format!("{:.1}", total / nq as f64 / 1e3),
            format!("{:.1}x", total / dram_total),
        ]);
    }

    println!("\npaper: traversal 2-15%, refinement (ssd+distance) dominates (>90% at depth);");
    println!("       all-in-DRAM upper bound up to 14x.");

    let ssd_one = SsdSim::new(&cfg.sim).idle_latency_ns();
    println!(
        "\none SSD vector fetch = {:.1} us (45 us device latency, Table I)",
        ssd_one / 1e3
    );
}
