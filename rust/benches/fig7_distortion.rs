//! Fig 7 — Distance-estimation distortion vs the top-100 ground truth.
//!
//! Paper claims (Wiki): with the same PQ base, FaTRQ's ternary residual
//! estimator reaches MSE 0.0159 vs 0.258 for 3-bit SQ residuals; plain
//! INT8 (no residual) is poor; 4-bit SQ reaches comparable MSE (0.0134)
//! at 2.4x the storage. The oracle line uses full-precision residuals.
//!
//! The SQ baseline follows the GPU refinement pipelines the paper cites
//! [12]: one global uniform scale for the whole dataset (per-record range
//! metadata is incompatible with branch-free GPU decode). Per-record
//! min/max SQ is also reported as a stronger variant — see DESIGN.md §7.

use fatrq::bench_support as bs;
use fatrq::config::IndexKind;
use fatrq::index::FlatIndex;
use fatrq::metrics::distance_mse;
use fatrq::quant::sq::{GlobalSq, Int8Quantizer, SqStore};
use fatrq::refine::ProgressiveEstimator;
use fatrq::util::{dot, l2_sq};

fn main() {
    println!("# Fig 7 — squared-L2 estimation distortion on top-100 GT pairs\n");
    let dataset = bs::bench_dataset();
    let sys = bs::build_bench_system(IndexKind::Ivf, dataset);
    let dim = sys.dataset.dim;
    let n = sys.dataset.count();

    // Residuals for the SQ baselines (same PQ base as FaTRQ).
    let mut deltas = vec![0f32; n * dim];
    for i in 0..n * dim {
        deltas[i] = sys.dataset.base[i] - sys.recon[i];
    }
    let gsq3 = GlobalSq::fit(&deltas, 3);
    let gsq4 = GlobalSq::fit(&deltas, 4);
    let psq3 = SqStore::build(&deltas, dim, 3);
    let int8 = Int8Quantizer::fit(&sys.dataset.base);

    let est = ProgressiveEstimator::new(&sys.trq, sys.cal.clone());
    let flat = FlatIndex::new(sys.dataset.base.clone(), dim);

    let mut truths = Vec::new();
    let mut e_int8 = Vec::new();
    let mut e_gsq3 = Vec::new();
    let mut e_gsq4 = Vec::new();
    let mut e_psq3 = Vec::new();
    let mut e_fatrq = Vec::new();
    let mut e_oracle = Vec::new();

    let mut recon_buf = vec![0f32; dim];
    let mut delta_buf = vec![0f32; dim];
    let mut codes = vec![0u8; dim];
    let mut int8_codes = vec![0i8; dim];
    let nq = sys.dataset.num_queries().min(64);
    for q in 0..nq {
        let query = sys.dataset.query(q);
        let qs = sys.scorer.for_query(query);
        for cand in flat.search_exact(query, 100) {
            let id = cand.id as usize;
            truths.push(cand.dist);
            let d0 = qs.score(id);
            let xc = &sys.recon[id * dim..(id + 1) * dim];
            let delta = &deltas[id * dim..(id + 1) * dim];

            // INT8 w/o RQ: reconstruct the full vector from int8.
            int8.encode_one(sys.dataset.vector(id), &mut int8_codes);
            int8.decode_one(&int8_codes, &mut recon_buf);
            e_int8.push(l2_sq(query, &recon_buf));

            // PQ + global-scale b-bit SQ residual: reconstruct x_c + SQ(δ).
            gsq3.encode_one(delta, &mut codes);
            gsq3.decode_one(&codes, &mut delta_buf);
            for d in 0..dim {
                recon_buf[d] = xc[d] + delta_buf[d];
            }
            e_gsq3.push(l2_sq(query, &recon_buf));

            gsq4.encode_one(delta, &mut codes);
            gsq4.decode_one(&codes, &mut delta_buf);
            for d in 0..dim {
                recon_buf[d] = xc[d] + delta_buf[d];
            }
            e_gsq4.push(l2_sq(query, &recon_buf));

            // Per-record-range SQ3 (stronger variant, extra metadata).
            psq3.decode(id, &mut delta_buf);
            for d in 0..dim {
                recon_buf[d] = xc[d] + delta_buf[d];
            }
            e_psq3.push(l2_sq(query, &recon_buf));

            // FaTRQ: progressive estimation, no reconstruction.
            e_fatrq.push(est.estimate(query, id, d0));

            // Oracle: exact decomposition with the fp residual.
            let exact = d0 + dot(delta, delta) + 2.0 * dot(xc, delta)
                - 2.0 * dot(query, delta);
            e_oracle.push(exact);
        }
    }

    bs::header(&["estimator", "MSE", "768-D bytes", "notes"]);
    let rows: Vec<(&str, &Vec<f32>, String, &str)> = vec![
        ("INT8 (w/o RQ)", &e_int8, "768".into(), "reconstructs, no residual"),
        ("PQ + SQ3 residual [12]", &e_gsq3, format!("{}", gsq3.record_bytes(768)), "global scale, reconstructs"),
        ("PQ + SQ4 residual [12]", &e_gsq4, format!("{}", gsq4.record_bytes(768)), "global scale, reconstructs"),
        ("PQ + SQ3 per-record", &e_psq3, "296".into(), "min/max metadata variant"),
        ("PQ + FaTRQ (ours)", &e_fatrq, "162".into(), "progressive, no reconstruction"),
        ("oracle (fp residual)", &e_oracle, "3072".into(), "exact decomposition"),
    ];
    for (name, est_vals, bytes, notes) in rows {
        bs::row(&[
            name.to_string(),
            format!("{:.5}", distance_mse(est_vals, &truths)),
            bytes,
            notes.to_string(),
        ]);
    }

    let mse_fatrq = distance_mse(&e_fatrq, &truths);
    let mse_sq3 = distance_mse(&e_gsq3, &truths);
    let mse_sq4 = distance_mse(&e_gsq4, &truths);
    println!(
        "\nFaTRQ vs 3-bit SQ: {:.1}x lower MSE at {:.1}x less storage (paper: 16.2x / 1.8x)",
        mse_sq3 / mse_fatrq,
        288.0 / 162.0
    );
    println!(
        "FaTRQ vs 4-bit SQ: {:.2}x MSE at {:.1}x less storage (paper: ~1.2x / 2.4x)",
        mse_fatrq / mse_sq4,
        384.0 / 162.0
    );
    println!("paper MSEs: FaTRQ 0.0159, SQ3 0.258, SQ4 0.0134 (768-D Wiki).");
}
