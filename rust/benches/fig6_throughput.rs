//! Fig 6 — End-to-end normalized throughput at matched recall.
//!
//! Paper claims: FaTRQ-HW is 3.1–9.4x over IVF-FAISS and 2.6–4.9x over
//! CAGRA-cuVS at 85/90/95% recall@10; HW adds 1.2–1.5x over SW; the gap
//! narrows at 95% recall; IVF benefits more because it refines more
//! candidates (§V-B: 320 vs 120 at 90% on Wiki; with FaTRQ those become
//! 28 vs 17 SSD reads).

use fatrq::bench_support as bs;
use fatrq::config::{IndexKind, RefineMode, SimConfig};
use fatrq::coordinator::BatchReport;
use fatrq::util::threadpool::default_threads;

/// Pipelined (steady-state, batched) throughput: with 10k in-flight
/// queries the paper's metric is bounded by the slowest *stage rate*, not
/// by per-query latency — SSD latency amortizes, SSD IOPS does not.
fn pipeline_qps(rep: &BatchReport, sim: &SimConfig, mode: RefineMode, threads: usize) -> f64 {
    let bd = &rep.breakdown;
    let mut rates = vec![
        // Front-stage device (the "GPU") is one serial resource.
        1e9 / bd.traversal_ns.max(1.0),
        // Exact rerank parallelizes across host cores.
        threads as f64 * 1e9 / bd.rerank_ns.max(1.0),
    ];
    if bd.ssd_reads > 0 {
        rates.push(sim.ssd_kiops * 1e3 / bd.ssd_reads as f64);
    }
    if bd.far_reads > 0 {
        let bytes = (bd.far_reads * 162) as f64;
        let bw = match mode {
            // SW streams records over the CXL link.
            RefineMode::FatrqSw => sim.cxl_bandwidth_gbps * 1e9,
            // HW reads device DRAM at full DIMM bandwidth.
            _ => 2.0 * sim.dram_clock_mhz * 1e6 * 8.0 * sim.dram_channels as f64,
        };
        rates.push(bw / bytes);
    }
    if bd.refine_compute_ns > 0.0 {
        let par = if mode == RefineMode::FatrqHw { 1.0 } else { threads as f64 };
        rates.push(par * 1e9 / bd.refine_compute_ns);
    }
    rates.iter().cloned().fold(f64::INFINITY, f64::min)
}

fn main() {
    println!("# Fig 6 — normalized throughput at matched recall@10\n");
    let dataset = bs::bench_dataset();
    let threads = default_threads();

    for kind in [IndexKind::Ivf, IndexKind::Graph] {
        let sys = bs::build_bench_system(kind, dataset.clone());
        let truth = bs::bench_truth(&sys);
        println!(
            "\n## front stage: {} (baseline = {})\n",
            kind.name(),
            if kind == IndexKind::Ivf { "IVF-FAISS" } else { "CAGRA-cuVS" }
        );
        bs::header(&[
            "recall target",
            "mode",
            "achieved recall",
            "cands",
            "far/query",
            "ssd/query",
            "latency (us)",
            "qps (pipelined)",
            "qps (wall, 1 core)",
            "norm throughput",
        ]);
        for target in [0.85, 0.90, 0.95] {
            let mut base_qps = None;
            for (mode, early_exit, label) in [
                (RefineMode::Baseline, false, "baseline".to_string()),
                (RefineMode::FatrqSw, false, "fatrq-sw".to_string()),
                (RefineMode::FatrqHw, false, "fatrq-hw".to_string()),
                (RefineMode::FatrqHw, true, "fatrq-hw+ee".to_string()),
            ] {
                match bs::tune_to_recall_opts(&sys, mode, &truth, target, threads, early_exit) {
                    Some(op) => {
                        let qps = pipeline_qps(&op.report, &sys.cfg.sim, mode, threads);
                        if mode == RefineMode::Baseline {
                            base_qps = Some(qps);
                        }
                        let norm = base_qps.map(|b| qps / b).unwrap_or(1.0);
                        bs::row(&[
                            format!("{:.0}%", target * 100.0),
                            label,
                            format!("{:.3}", op.recall),
                            op.candidates.to_string(),
                            op.report.breakdown.far_reads.to_string(),
                            op.report.breakdown.ssd_reads.to_string(),
                            format!("{:.1}", op.report.mean_latency_ns / 1e3),
                            format!("{qps:.0}"),
                            format!("{:.0}", op.report.wall_qps),
                            format!("{norm:.2}x"),
                        ]);
                    }
                    None => {
                        bs::row(&[
                            format!("{:.0}%", target * 100.0),
                            label,
                            "unreachable".into(),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                            "-".into(),
                        ]);
                    }
                }
            }
        }
    }
    println!("\npaper: FaTRQ-HW 3.1-9.4x vs IVF baseline, 2.6-4.9x vs graph baseline;");
    println!("       HW 1.2-1.5x over SW; speedup narrows at 95% recall.");
}
