//! Property-based invariants over the coordinator's core state machines
//! (routing, ranking, filtering, codecs), via the in-repo mini property
//! harness (`fatrq::util::prop` — no proptest crate offline).

use fatrq::config::{FaultConfig, SimConfig};
use fatrq::kernels::ternary::{qdot_packed_tab, TernaryQueryLut};
use fatrq::quant::pack::{pack_ternary, packed_len, unpack_ternary};
use fatrq::quant::trq::{encode_record, estimate_qdot, qdot_packed, ternary_encode};
use fatrq::refine::filter::{filter_top_ratio, provable_cutoff};
use fatrq::simulator::{FarStream, FaultPlan, LaneServer, SharedTimeline, SsdQueue, TimelineSched};
use fatrq::util::prop::{forall, vec_gauss, Config};
use fatrq::util::rng::Rng;
use fatrq::util::topk::{Scored, TopK};
use fatrq::util::{dot, norm};

#[test]
fn prop_topk_matches_full_sort() {
    forall(
        Config { cases: 200, seed: 1, max_size: 400 },
        |rng: &mut Rng, size: usize| -> Vec<f32> {
            (0..size.max(1)).map(|_| rng.f32() * 100.0).collect()
        },
        |dists| {
            let k = (dists.len() / 3).max(1);
            let mut t = TopK::new(k);
            for (i, &d) in dists.iter().enumerate() {
                t.push(d, i as u64);
            }
            let got: Vec<f32> = t.into_sorted().iter().map(|s| s.dist).collect();
            let mut want = dists.clone();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            want.truncate(k);
            got == want
        },
    );
}

#[test]
fn prop_pack_unpack_roundtrip() {
    forall(
        Config { cases: 150, seed: 2, max_size: 800 },
        |rng: &mut Rng, size: usize| -> Vec<i8> {
            (0..size.max(1)).map(|_| rng.below(3) as i8 - 1).collect()
        },
        |trits| {
            let mut packed = vec![0u8; packed_len(trits.len())];
            pack_ternary(trits, &mut packed);
            let mut back = vec![0i8; trits.len()];
            unpack_ternary(&packed, trits.len(), &mut back);
            back == *trits
        },
    );
}

#[test]
fn prop_ternary_alignment_bounds() {
    // Alignment must be in (0, 1] for nonzero residuals, and the encoded
    // inner product must equal alignment * ||delta||.
    forall(
        Config { cases: 120, seed: 3, max_size: 256 },
        vec_gauss(64),
        |delta| {
            let code = ternary_encode(delta);
            let n = norm(delta);
            if n < 1e-6 {
                return code.k == 0;
            }
            if !(code.alignment > 0.0 && code.alignment <= 1.0 + 1e-6) {
                return false;
            }
            // <e_delta, e_code> recomputed from the trits:
            let ip: f32 = delta
                .iter()
                .zip(&code.trits)
                .map(|(&d, &t)| d * t as f32)
                .sum();
            let recomputed = ip / ((code.k as f32).sqrt() * n);
            (recomputed - code.alignment).abs() < 1e-4
        },
    );
}

#[test]
fn prop_ternary_code_is_argmax_over_neighbors() {
    // Local optimality: flipping any single trit to another value cannot
    // improve the normalized inner product (necessary condition of the
    // global optimum the O(D log D) algorithm claims).
    forall(
        Config { cases: 60, seed: 4, max_size: 64 },
        vec_gauss(12),
        |delta| {
            let n = norm(delta);
            if n < 1e-6 {
                return true;
            }
            let e: Vec<f32> = delta.iter().map(|x| x / n).collect();
            let code = ternary_encode(delta);
            let obj = |trits: &[i8]| -> f32 {
                let k: f32 = trits.iter().filter(|&&t| t != 0).count() as f32;
                if k == 0.0 {
                    return f32::MIN;
                }
                trits
                    .iter()
                    .zip(&e)
                    .map(|(&t, &x)| t as f32 * x)
                    .sum::<f32>()
                    / k.sqrt()
            };
            let best = obj(&code.trits);
            for i in 0..code.trits.len() {
                for v in [-1i8, 0, 1] {
                    if v == code.trits[i] {
                        continue;
                    }
                    let mut alt = code.trits.clone();
                    alt[i] = v;
                    if obj(&alt) > best + 1e-5 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_qdot_estimate_scales_with_query() {
    forall(
        Config { cases: 80, seed: 5, max_size: 128 },
        vec_gauss(40),
        |delta| {
            let mut rng = Rng::new(dot(delta, delta).to_bits() as u64);
            let q: Vec<f32> = (0..delta.len()).map(|_| rng.gaussian_f32()).collect();
            let rec = encode_record(delta, &vec![0.0; delta.len()]);
            let base = estimate_qdot(&q, &rec, delta.len());
            let q2: Vec<f32> = q.iter().map(|x| 3.0 * x).collect();
            let scaled = estimate_qdot(&q2, &rec, delta.len());
            (scaled - 3.0 * base).abs() < 1e-3 * base.abs().max(1.0)
        },
    );
}

#[test]
fn prop_qdot_packed_counts_nonzeros() {
    forall(
        Config { cases: 80, seed: 6, max_size: 256 },
        vec_gauss(50),
        |delta| {
            let code = ternary_encode(delta);
            let mut packed = vec![0u8; packed_len(delta.len())];
            pack_ternary(&code.trits, &mut packed);
            let q = vec![1.0f32; delta.len()];
            let (_, k) = qdot_packed(&q, &packed, delta.len());
            k == code.k
        },
    );
}

#[test]
fn prop_ternary_table_kernel_matches_byte_lut() {
    // The kernel-layer contract: the per-query ADC-table kernel is
    // bit-for-bit identical in f32 (and in k*) to the byte-LUT fallback
    // for every valid packed code, at any dimensionality — ragged tails
    // included — so the amortization threshold can never change a result.
    forall(
        Config { cases: 120, seed: 12, max_size: 800 },
        |rng: &mut Rng, size: usize| -> Vec<f32> {
            (0..size.max(1)).map(|_| rng.gaussian_f32()).collect()
        },
        |delta| {
            let dim = delta.len();
            let code = ternary_encode(delta);
            let mut packed = vec![0u8; packed_len(dim)];
            pack_ternary(&code.trits, &mut packed);
            let mut rng = Rng::new(dim as u64 ^ 0xAB);
            let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            let mut tab = TernaryQueryLut::new();
            tab.build(&q);
            qdot_packed_tab(&tab, &packed) == qdot_packed(&q, &packed, dim)
        },
    );
}

#[test]
fn prop_filter_invariants() {
    // filter_top_ratio: keeps a prefix, at least k, at most all; the kept
    // prefix is exactly the lowest-scored candidates.
    forall(
        Config { cases: 150, seed: 7, max_size: 300 },
        |rng: &mut Rng, size: usize| -> (Vec<f32>, f64, usize) {
            let n = size.max(2);
            let mut d: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (d, rng.f64(), 1 + rng.below(n))
        },
        |(dists, ratio, k)| {
            let refined: Vec<Scored> = dists
                .iter()
                .enumerate()
                .map(|(i, &d)| Scored::new(d, i as u64))
                .collect();
            let kept = filter_top_ratio(&refined, *ratio, *k);
            kept.len() >= (*k).min(refined.len())
                && kept.len() <= refined.len()
                && kept == refined[..kept.len()]
        },
    );
}

#[test]
fn prop_provable_cutoff_never_drops_topk() {
    forall(
        Config { cases: 150, seed: 8, max_size: 300 },
        |rng: &mut Rng, size: usize| -> (Vec<f32>, f32) {
            let n = size.max(2);
            let mut d: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0).collect();
            d.sort_by(|a, b| a.partial_cmp(b).unwrap());
            (d, rng.f32())
        },
        |(dists, margin)| {
            let refined: Vec<Scored> = dists
                .iter()
                .enumerate()
                .map(|(i, &d)| Scored::new(d, i as u64))
                .collect();
            let k = (dists.len() / 4).max(1);
            let kept = provable_cutoff(&refined, k, *margin);
            // Must keep at least k, keep a prefix, and with zero margin the
            // kth candidate must still be present.
            kept.len() >= k.min(refined.len()) && kept == refined[..kept.len()]
        },
    );
}

#[test]
fn prop_estimator_unbiased_on_isotropic_residuals() {
    // Statistical: over random isotropic residuals, the mean signed error
    // of the qdot estimator is near zero relative to its scale (§III-B's
    // zero-expectation orthogonal-term claim).
    let dim = 96;
    let mut rng = Rng::new(99);
    let mut err_sum = 0.0f64;
    let mut mag_sum = 0.0f64;
    let trials = 600;
    for _ in 0..trials {
        let delta: Vec<f32> = (0..dim).map(|_| 0.2 * rng.gaussian_f32()).collect();
        let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let rec = encode_record(&delta, &vec![0.0; dim]);
        let est = estimate_qdot(&q, &rec, dim);
        let truth = dot(&q, &delta);
        err_sum += (est - truth) as f64;
        mag_sum += (truth as f64).abs();
    }
    let bias = err_sum / trials as f64;
    let scale = mag_sum / trials as f64;
    assert!(
        bias.abs() < 0.1 * scale,
        "bias {bias:.5} vs mean |signal| {scale:.5}"
    );
}

/// Generator for a batch of random far-memory record streams (mixed HW/SW
/// modes, scattered record addresses — the shape the engine captures).
fn gen_streams(rng: &mut Rng, size: usize) -> Vec<FarStream> {
    let batch = 1 + rng.below(6);
    (0..batch)
        .map(|_| {
            let n = 1 + rng.below(size.max(2));
            FarStream {
                local: rng.below(2) == 0,
                rec_bytes: 26 + rng.below(140),
                addrs: (0..n).map(|_| rng.next_u64() % (1 << 30)).collect(),
            }
        })
        .collect()
}

#[test]
fn prop_shared_timeline_batch_of_one_reduces_to_independent() {
    forall(
        Config { cases: 60, seed: 31, max_size: 150 },
        gen_streams,
        |streams| {
            let tl = SharedTimeline::new(&SimConfig::default());
            // Every stream scheduled alone must reproduce the private
            // independent-device completion bit-for-bit, with zero queue.
            streams.iter().all(|s| {
                let t = tl.schedule(std::slice::from_ref(s));
                t[0].shared_ns == tl.solo(s) && t[0].queue_ns == 0.0
            })
        },
    );
}

#[test]
fn prop_shared_timeline_monotone_and_work_conserving() {
    forall(
        Config { cases: 40, seed: 32, max_size: 120 },
        gen_streams,
        |streams| {
            let tl = SharedTimeline::new(&SimConfig::default());
            let mut prev_makespan = 0.0f64;
            for n in 1..=streams.len() {
                let t = tl.schedule(&streams[..n]);
                // (a) monotone: contention never speeds a stream up, and
                // batch completion never shrinks as the batch grows.
                if t.iter().any(|ti| ti.shared_ns < ti.solo_ns) {
                    return false;
                }
                let makespan = t.iter().map(|ti| ti.shared_ns).fold(0.0f64, f64::max);
                if makespan < prev_makespan {
                    return false;
                }
                // (b) work-conserving: never slower than running the
                // streams fully serialized (sum of solo completions).
                let serialized: f64 = t.iter().map(|ti| ti.solo_ns).sum();
                if makespan > serialized * (1.0 + 1e-9) + 1.0 {
                    return false;
                }
                prev_makespan = makespan;
            }
            true
        },
    );
}

#[test]
fn prop_shared_timeline_deterministic() {
    forall(
        Config { cases: 30, seed: 33, max_size: 100 },
        gen_streams,
        |streams| {
            let tl = SharedTimeline::new(&SimConfig::default());
            let a = tl.schedule(streams);
            let b = tl.schedule(streams);
            a.iter().zip(&b).all(|(x, y)| {
                x.shared_ns == y.shared_ns && x.solo_ns == y.solo_ns
            })
        },
    );
}

// ---------------------------------------------------------------------
// Generic resource server: the one FCFS idle-reduction queueing policy
// behind the far-memory timeline, the SSD queue and the CPU lane server.
// ---------------------------------------------------------------------

#[test]
fn prop_lane_server_fcfs_work_conserving_and_never_beats_solo() {
    forall(
        Config { cases: 80, seed: 34, max_size: 60 },
        |rng: &mut Rng, size: usize| -> Vec<f64> {
            (0..size.max(1)).map(|_| (1 + rng.below(1000)) as f64).collect()
        },
        |durs| {
            for lanes in [1usize, 2, 3] {
                let mut s = LaneServer::new(lanes);
                let mut at = 0.0f64;
                let mut grants = Vec::with_capacity(durs.len());
                for (i, &d) in durs.iter().enumerate() {
                    at += (i % 3) as f64 * 0.5; // staggered, non-decreasing
                    grants.push((at, s.admit(d, at)));
                }
                let total: f64 = durs.iter().sum();
                let makespan =
                    grants.iter().map(|(_, g)| g.done_ns).fold(0.0f64, f64::max);
                let last_at = grants.last().unwrap().0;
                // Work conservation: never worse than serializing all
                // remaining work after the last admission.
                if makespan > last_at + total * (1.0 + 1e-9) + 1e-6 {
                    return false;
                }
                for (at, g) in &grants {
                    // Never faster than the intrinsic duration; queueing
                    // accounted non-negative.
                    if g.done_ns + 1e-9 < at + g.solo_ns || g.queue_ns < 0.0 {
                        return false;
                    }
                }
                // Single lane: FCFS — completion order is admission order.
                if lanes == 1 {
                    let mut last = 0.0f64;
                    for (_, g) in &grants {
                        if g.done_ns + 1e-9 < last {
                            return false;
                        }
                        last = g.done_ns;
                    }
                }
            }
            true
        },
    );
}

#[test]
fn prop_ssd_queue_fcfs_and_idle_reduction() {
    forall(
        Config { cases: 60, seed: 35, max_size: 40 },
        |rng: &mut Rng, size: usize| -> Vec<(usize, f64)> {
            (0..size.max(1))
                .map(|_| (1 + rng.below(50), rng.below(200_000) as f64))
                .collect()
        },
        |bursts| {
            let cfg = SimConfig::default();
            let mut q = SsdQueue::new(&cfg);
            let mut at = 0.0f64;
            let mut last_done = 0.0f64;
            for &(reads, gap) in bursts {
                at += gap;
                let g = q.admit(reads, 3072, at);
                // FCFS: bursts complete in admission order.
                if g.done_ns + 1e-9 < last_done {
                    return false;
                }
                last_done = g.done_ns;
                // Never beats the intrinsic burst; queue accounting
                // consistent with completion.
                if g.done_ns + 1e-9 < at + g.solo_ns || g.queue_ns < 0.0 {
                    return false;
                }
                // Idle reduction, exact: a burst admitted to a drained
                // token server is served in exactly its solo time.
                let idle = q.admit(reads, 3072, last_done + 1e9);
                if idle.queue_ns != 0.0 || idle.done_ns != last_done + 1e9 + idle.solo_ns {
                    return false;
                }
                last_done = idle.done_ns;
                at = last_done;
            }
            true
        },
    );
}

#[test]
fn prop_record_interleave_batch1_exact_and_work_conserving() {
    forall(
        Config { cases: 40, seed: 36, max_size: 100 },
        gen_streams,
        |streams| {
            let cfg = SimConfig::default();
            let tl = SharedTimeline::new(&cfg);
            // Batch-1 exact at arbitrary admission instants: a lone
            // stream on the record-interleaved scheduler is served in
            // exactly its intrinsic time, bit-for-bit, zero queue.
            for (i, s) in streams.iter().enumerate() {
                let solo = tl.solo(s);
                let mut sched = TimelineSched::new(&cfg);
                let at = (i * 13_339) as f64;
                let t = sched.admit_interleaved(s, at);
                if t[0].1.solo_ns != solo
                    || t[0].1.shared_ns != at + solo
                    || t[0].1.queue_ns != 0.0
                {
                    return false;
                }
            }
            // Staggered admissions: monotone vs solo, work conserving.
            let mut sched = TimelineSched::new(&cfg);
            let mut last = Vec::new();
            let mut ats = Vec::with_capacity(streams.len());
            for (i, s) in streams.iter().enumerate() {
                let at = i as f64 * 2_000.0;
                ats.push(at);
                last = sched.admit_interleaved(s, at);
            }
            let serialized: f64 = last.iter().map(|(_, t)| t.solo_ns).sum();
            let makespan = last.iter().map(|(_, t)| t.shared_ns).fold(0.0f64, f64::max);
            if makespan > ats.last().unwrap() + serialized * (1.0 + 1e-9) + 1.0 {
                return false;
            }
            for &(q, t) in &last {
                if t.shared_ns + 1e-6 < ats[q] + t.solo_ns {
                    return false;
                }
            }
            true
        },
    );
}

// ---------------------------------------------------------------------
// Seeded fault injection: plan purity and retry scheduling.
// ---------------------------------------------------------------------

#[test]
fn prop_fault_plan_draws_are_pure_across_worker_interleavings() {
    // The worker-count determinism contract at its root: a fault draw is
    // a stateless hash, so 1 worker walking the tasks in order and 4
    // workers walking them strided (with other channels consulted in
    // between, as a real event interleaving would) see identical
    // verdicts, and a fresh plan from the same config replays them.
    forall(
        Config { cases: 60, seed: 37, max_size: 300 },
        |rng: &mut Rng, size: usize| -> (u64, f64, f64, usize) {
            (rng.next_u64(), rng.f64(), rng.f64(), size.max(10))
        },
        |&(seed, far_rate, ssd_rate, n)| {
            let cfg = FaultConfig {
                seed,
                far_fail_rate: far_rate,
                ssd_fail_rate: ssd_rate,
                ..Default::default()
            };
            let plan = FaultPlan::new(cfg.clone());
            let seq: Vec<bool> = (0..n).map(|t| plan.far_read_fails(t, 0)).collect();
            let mut strided = vec![false; n];
            for w in 0..4usize {
                let mut t = w;
                while t < n {
                    let _ = plan.ssd_read_fails(w, t, 1);
                    let _ = plan.far_spike_ns(t, 0);
                    strided[t] = plan.far_read_fails(t, 0);
                    t += 4;
                }
            }
            if seq != strided {
                return false;
            }
            let replay = FaultPlan::new(cfg);
            (0..n).all(|t| replay.far_read_fails(t, 0) == seq[t])
        },
    );
}

#[test]
fn prop_retry_readmissions_preserve_fcfs_and_work_conservation() {
    // The scheduler's retry policy re-enters a failed read through the
    // time-ordered event heap after a deterministic backoff — to the
    // shared device it is just a later admission. Replaying that exact
    // pattern (retry chains expanded per the plan's draws, admissions
    // delivered in time order like the heap does) must keep the resource
    // server's FCFS completion order and work conservation.
    forall(
        Config { cases: 60, seed: 38, max_size: 40 },
        |rng: &mut Rng, size: usize| -> Vec<(usize, f64, u32)> {
            (0..size.max(1))
                .map(|_| {
                    (1 + rng.below(40), rng.below(50_000) as f64, rng.below(3) as u32)
                })
                .collect()
        },
        |bursts| {
            let cfg = SimConfig::default();
            let plan = FaultPlan::new(FaultConfig {
                seed: 77,
                ssd_fail_rate: 0.5,
                retry_backoff_us: 20.0,
                ..Default::default()
            });
            // Expand every burst into its retry chain: attempt a + 1
            // re-enters backoff(a) after a failed draw of attempt a.
            let mut events: Vec<(f64, usize)> = Vec::new();
            let mut at = 0.0f64;
            for (t, &(reads, gap, budget)) in bursts.iter().enumerate() {
                at += gap;
                let mut when = at;
                events.push((when, reads));
                for a in 0..budget {
                    if !plan.ssd_read_fails(0, t, a) {
                        break;
                    }
                    when += plan.backoff_ns(a);
                    events.push((when, reads));
                }
            }
            events.sort_by(|x, y| x.0.partial_cmp(&y.0).unwrap());
            let mut q = SsdQueue::new(&cfg);
            let mut last_done = 0.0f64;
            let mut total = 0.0f64;
            for &(when, reads) in &events {
                let g = q.admit(reads, 3072, when);
                // FCFS, never beating the intrinsic burst, sane queueing.
                if g.done_ns + 1e-9 < last_done
                    || g.done_ns + 1e-9 < when + g.solo_ns
                    || g.queue_ns < 0.0
                {
                    return false;
                }
                last_done = g.done_ns;
                total += g.solo_ns;
            }
            // Work conservation across the whole retry-laden schedule.
            let last_at = events.last().unwrap().0;
            last_done <= last_at + total * (1.0 + 1e-9) + 1e-6
        },
    );
}
