//! Integration: full system build + all three refinement modes, on both
//! front-stage indexes, checking the paper's qualitative claims hold
//! end-to-end (fewer SSD reads, lower latency, preserved recall).

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, ground_truth, run_batch, Pipeline};
use fatrq::index::FlatIndex;
use fatrq::metrics::recall_at_k;

fn cfg(kind: IndexKind) -> SystemConfig {
    SystemConfig {
        dataset: DatasetConfig {
            dim: 96,
            count: 6000,
            clusters: 48,
            noise: 0.35,
            query_noise: 1.0,
            queries: 32,
            seed: 77,
        },
        quant: QuantConfig { pq_m: 24, pq_nbits: 6, kmeans_iters: 6, train_sample: 4000 },
        index: IndexConfig {
            kind,
            nlist: 64,
            nprobe: 16,
            graph_degree: 20,
            ef_search: 96,
            ef_construction: 96,
        },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.25,
            calib_sample: 0.01,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn ivf_pipeline_reproduces_paper_claims() {
    let sys = build_system(&cfg(IndexKind::Ivf)).unwrap();
    let truth = ground_truth(&sys, 10);
    let base = run_batch(&sys, RefineMode::Baseline, &truth, 4);
    let sw = run_batch(&sys, RefineMode::FatrqSw, &truth, 4);
    let hw = run_batch(&sys, RefineMode::FatrqHw, &truth, 4);

    // SSD traffic: FaTRQ cuts it several-fold (paper: 320 -> 28).
    assert!(
        (hw.breakdown.ssd_reads as f64) < 0.45 * base.breakdown.ssd_reads as f64,
        "hw ssd {} vs baseline {}",
        hw.breakdown.ssd_reads,
        base.breakdown.ssd_reads
    );
    // Latency: the deterministic (simulated-device) component must beat
    // the baseline outright; the full mean (which includes measured host
    // time subject to test-harness CPU contention) gets 15% slack.
    let sim_ns = |r: &fatrq::coordinator::BatchReport| r.breakdown.ssd_ns + r.breakdown.far_ns;
    assert!(sim_ns(&hw) < sim_ns(&base), "hw sim {} !< base sim {}", sim_ns(&hw), sim_ns(&base));
    assert!(sim_ns(&sw) < sim_ns(&base), "sw sim {} !< base sim {}", sim_ns(&sw), sim_ns(&base));
    // (wall-clock means include measured host time; debug builds and
    // parallel test execution add noise, hence the slack — the simulated
    // components above are the strict, deterministic claim.)
    // Wall-clock latency claims are only meaningful in release builds —
    // debug-mode host compute is ~10-30x slower and the parallel test
    // harness adds contention; the simulated-device assertions above are
    // the strict invariant in every build.
    if !cfg!(debug_assertions) {
        assert!(hw.mean_latency_ns < 1.15 * base.mean_latency_ns);
        assert!(sw.mean_latency_ns < 1.25 * base.mean_latency_ns);
    }
    assert!(hw.breakdown.far_ns < sw.breakdown.far_ns);
    // Recall stays close to the all-SSD baseline.
    assert!(
        hw.mean_recall > base.mean_recall - 0.08,
        "recall dropped: {} vs {}",
        hw.mean_recall,
        base.mean_recall
    );
}

#[test]
fn graph_pipeline_reproduces_paper_claims() {
    let sys = build_system(&cfg(IndexKind::Graph)).unwrap();
    let truth = ground_truth(&sys, 10);
    let base = run_batch(&sys, RefineMode::Baseline, &truth, 4);
    let hw = run_batch(&sys, RefineMode::FatrqHw, &truth, 4);
    assert!(hw.breakdown.ssd_reads < base.breakdown.ssd_reads);
    // Deterministic device time must win outright; wall-clock gets slack
    // (see the IVF test's note).
    assert!(
        hw.breakdown.ssd_ns + hw.breakdown.far_ns
            < base.breakdown.ssd_ns + base.breakdown.far_ns
    );
    if !cfg!(debug_assertions) {
        assert!(hw.mean_latency_ns < 1.15 * base.mean_latency_ns);
    }
    assert!(hw.mean_recall > base.mean_recall - 0.10);
}

#[test]
fn deeper_filtering_recovers_recall() {
    // Fig 8's mechanism: raising the filter ratio converges to baseline
    // recall.
    let sys = build_system(&cfg(IndexKind::Ivf)).unwrap();
    let flat = FlatIndex::new(sys.dataset.base.clone(), sys.dataset.dim);
    let nq = sys.dataset.num_queries();
    let mut recalls = Vec::new();
    for ratio in [0.05, 0.25, 1.0] {
        let mut p = Pipeline::new(&sys);
        p.filter_ratio = ratio;
        let mut r = 0.0;
        for q in 0..nq {
            let query = sys.dataset.query(q);
            let out = p.query(query);
            r += recall_at_k(&out.topk, &flat.search_exact(query, 10), 10);
        }
        recalls.push(r / nq as f64);
    }
    assert!(
        recalls[2] >= recalls[0] - 1e-9,
        "full refinement {} < tight filter {}",
        recalls[2],
        recalls[0]
    );
    // Full-ratio FaTRQ == baseline refinement (every candidate fetched).
    let base = Pipeline::new(&sys).with_mode(RefineMode::Baseline);
    let mut r_base = 0.0;
    for q in 0..nq {
        let query = sys.dataset.query(q);
        r_base += recall_at_k(&base.query(query).topk, &flat.search_exact(query, 10), 10);
    }
    assert!((recalls[2] - r_base / nq as f64).abs() < 1e-9);
}

#[test]
fn breakdown_totals_are_consistent() {
    let sys = build_system(&cfg(IndexKind::Ivf)).unwrap();
    let p = Pipeline::new(&sys);
    let out = p.query(sys.dataset.query(0));
    let bd = out.breakdown;
    let sum = bd.traversal_ns + bd.far_ns + bd.refine_compute_ns + bd.ssd_ns + bd.rerank_ns;
    assert!((sum - bd.total_ns()).abs() < 1e-6);
    assert!(bd.refine_share() > 0.0 && bd.refine_share() < 1.0);
    assert_eq!(bd.candidates, 120);
}

#[test]
fn results_deterministic_across_runs() {
    let sys = build_system(&cfg(IndexKind::Ivf)).unwrap();
    let p = Pipeline::new(&sys);
    let a = p.query(sys.dataset.query(3));
    let b = p.query(sys.dataset.query(3));
    assert_eq!(a.topk, b.topk);
}
