//! Far-memory CXL device pool — end-to-end invariants.
//!
//! - **1-device bit-identity**: with `far.devices = 1` every placement
//!   policy (and the QoS-share knob left off) reproduces the untouched
//!   single-timeline clock bit-for-bit — top-k, queue_ns, per-query done
//!   times and makespan — across flat/IVF front stages × all refine
//!   modes (+ early-exit) × depths {1, 4, 16} × burst/record
//!   interleaving. The unit suite (`simulator::farpool`) additionally
//!   pins the pool against a bare `TimelineSched` admission for
//!   admission; this file pins the full serving clock.
//! - **placement never changes results**: any device count × placement
//!   returns the captured top-k — placement is a timing concern only.
//! - **worker-count determinism**: the pooled timeline is identical
//!   across 1 vs 4 pool workers and repeated runs.
//! - **pool contention relief**: total far-pool queueing is monotone
//!   non-increasing in the device count (same admission instants, work
//!   split over more independent timelines).
//! - **replica failover**: seeded far-read faults on replicated ranges
//!   fail over deterministically, recovered queries keep exact results,
//!   and a zero-rate fault plan is inert with the pool on.
//! - **tenant QoS far shares**: weighted record rotation keeps every
//!   tenant's queries completing (non-starvation) and stays
//!   work-conserving.

use fatrq::config::{
    DatasetConfig, FarPlacement, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode,
    StreamInterleave, SystemConfig, TenantSpec,
};
use fatrq::coordinator::{build_system_with, QueryEngine, QueryParams};
use fatrq::vecstore::synthesize;
use std::sync::Arc;

fn cfg(kind: IndexKind) -> SystemConfig {
    let mut cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 32,
            count: 1600,
            clusters: 12,
            noise: 0.3,
            query_noise: 0.8,
            queries: 10,
            seed: 23,
        },
        quant: QuantConfig { pq_m: 8, pq_nbits: 5, kmeans_iters: 6, train_sample: 1200 },
        index: IndexConfig { kind, nlist: 16, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.02,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.sim.shared_timeline = true;
    cfg
}

const PLACEMENTS: [FarPlacement; 3] =
    [FarPlacement::Interleave, FarPlacement::ShardAffine, FarPlacement::ReplicateHot];

#[test]
fn one_device_pool_is_bit_identical_under_every_placement() {
    // The tentpole contract, runtime-asserted end to end: a 1-device
    // pool is the legacy single-timeline clock bit-for-bit no matter
    // the placement policy.
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
        let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
        for (mode, early_exit) in [
            (RefineMode::Baseline, false),
            (RefineMode::FatrqSw, false),
            (RefineMode::FatrqHw, false),
            (RefineMode::FatrqHw, true),
        ] {
            let params =
                QueryParams::from_config(&cfg).with_mode(mode).with_early_exit(early_exit);
            let base = engine.profile_with(&params, &dataset.queries);
            let mut pooled = engine.profile_with(&params, &dataset.queries);
            pooled.set_far_devices(1);
            for placement in PLACEMENTS {
                pooled.set_far_placement(placement);
                for depth in [1usize, 4, 16] {
                    let (a, ra) = base.schedule(depth, 0.0);
                    let (b, rb) = pooled.schedule(depth, 0.0);
                    let tag = format!(
                        "{}/{mode:?}/ee={early_exit}/{placement:?}/depth={depth}",
                        kind.name()
                    );
                    assert_eq!(ra.makespan_ns, rb.makespan_ns, "{tag}: makespan");
                    assert!(!rb.farpool.active, "{tag}: 1-device pool reported active");
                    for q in 0..a.len() {
                        assert_eq!(a[q].topk, b[q].topk, "{tag}: query {q} top-k");
                        assert_eq!(
                            a[q].breakdown.queue_ns, b[q].breakdown.queue_ns,
                            "{tag}: query {q} queue"
                        );
                        assert_eq!(
                            ra.timings[q].done_ns, rb.timings[q].done_ns,
                            "{tag}: query {q} done"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn one_device_record_mode_is_bit_identical_under_every_placement() {
    // Record-level interleaving rides the pool's registration space —
    // with one device pool regs equal device regs, so the re-arbitrated
    // clock must be untouched too.
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.sim.stream_interleave = StreamInterleave::Record;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let base = engine.profile_with(engine.params(), &dataset.queries);
    let mut pooled = engine.profile_with(engine.params(), &dataset.queries);
    pooled.set_far_devices(1);
    for placement in PLACEMENTS {
        pooled.set_far_placement(placement);
        for depth in [1usize, 8] {
            let (a, ra) = base.schedule(depth, 0.0);
            let (b, rb) = pooled.schedule(depth, 0.0);
            assert_eq!(ra.makespan_ns, rb.makespan_ns, "{placement:?}/depth={depth}");
            for q in 0..a.len() {
                assert_eq!(a[q].topk, b[q].topk, "{placement:?}/{depth}: query {q}");
                assert_eq!(
                    a[q].breakdown.queue_ns, b[q].breakdown.queue_ns,
                    "{placement:?}/{depth}: query {q} queue"
                );
                assert_eq!(
                    ra.timings[q].done_ns, rb.timings[q].done_ns,
                    "{placement:?}/{depth}: query {q} done"
                );
            }
        }
    }
}

#[test]
fn placement_and_device_count_never_change_topk() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let base = engine.profile_with(engine.params(), &dataset.queries);
    let (want, _) = base.schedule(8, 0.0);
    let mut pooled = engine.profile_with(engine.params(), &dataset.queries);
    for devices in [2usize, 4] {
        pooled.set_far_devices(devices);
        for placement in PLACEMENTS {
            pooled.set_far_placement(placement);
            let (outs, rep) = pooled.schedule(8, 0.0);
            assert!(rep.farpool.active, "{devices}/{placement:?}: pool inactive");
            assert_eq!(rep.farpool.queue_ns.len(), devices);
            assert_eq!(rep.farpool.admissions.len(), devices);
            for q in 0..want.len() {
                assert_eq!(
                    outs[q].topk, want[q].topk,
                    "{devices} devices/{placement:?}: query {q} top-k moved"
                );
            }
        }
    }
}

#[test]
fn pooled_timeline_is_deterministic_across_worker_counts() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let mut p1 = e1.profile_with(e1.params(), &dataset.queries);
    let mut p4 = e4.profile_with(e4.params(), &dataset.queries);
    for p in [&mut p1, &mut p4] {
        p.set_far_devices(4);
        p.set_far_placement(FarPlacement::ReplicateHot);
        p.set_far_replicas(2);
        p.set_far_hot_alpha(0.5);
    }
    let (a, ra) = p1.schedule(8, 0.0);
    let (b, rb) = p4.schedule(8, 0.0);
    // Repeated schedule off the same profile must not drift either.
    let (_, rc) = p4.schedule(8, 0.0);
    assert_eq!(ra.makespan_ns, rb.makespan_ns, "1 vs 4 workers");
    assert_eq!(rb.makespan_ns, rc.makespan_ns, "repeated schedule");
    assert_eq!(ra.farpool, rb.farpool, "pool accounting must be worker-independent");
    assert_eq!(rb.farpool, rc.farpool);
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}");
        assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "query {q}");
        assert_eq!(ra.timings[q].done_ns, rb.timings[q].done_ns, "query {q}");
    }
}

#[test]
fn more_devices_never_increase_pool_queueing() {
    // Depth 0 admits the whole batch at t = 0, so every far admission
    // instant is fixed by the front-stage profiles alone — adding
    // devices only splits the same admissions over more independent
    // timelines, and total pool queueing must not grow.
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.dataset.queries = 16;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    profile.set_far_placement(FarPlacement::Interleave);
    let mut prev = f64::INFINITY;
    for devices in [1usize, 2, 4] {
        profile.set_far_devices(devices);
        let (_, rep) = profile.schedule(0, 0.0);
        let total = rep.farpool.total_queue_ns();
        assert!(
            total <= prev * (1.0 + 1e-9) || prev == f64::INFINITY,
            "pool queueing grew with devices: {devices} devices {total} ns > {prev} ns"
        );
        assert!(total >= 0.0);
        if devices == 1 {
            assert!(total > 0.0, "16 co-admitted streams must contend on one device");
        }
        prev = total;
    }
}

#[test]
fn replica_failover_recovers_exact_results_and_zero_rate_plans_are_inert() {
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.dataset.queries = 12;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let pool_on = |p: &mut fatrq::coordinator::BatchProfile| {
        p.set_far_devices(4);
        p.set_far_placement(FarPlacement::ReplicateHot);
        p.set_far_replicas(2);
        // Every range hot: every stream is replicated, so every far
        // fault exercises the failover rotation before any backoff.
        p.set_far_hot_alpha(1.0);
    };

    // Zero-fault baseline + inertness: a pool schedule with a zero-rate
    // fault plan is bit-identical to one without the fault layer.
    let mut base = e4.profile_with(e4.params(), &dataset.queries);
    pool_on(&mut base);
    let (want, rep_nofault) = base.schedule(8, 0.0);
    let mut inert = e4.profile_with(e4.params(), &dataset.queries);
    pool_on(&mut inert);
    inert.set_fault(fatrq::config::FaultConfig { seed: 77, ..Default::default() });
    let (outs_inert, rep_inert) = inert.schedule(8, 0.0);
    assert_eq!(rep_nofault.makespan_ns, rep_inert.makespan_ns, "zero-rate plan moved the clock");
    assert_eq!(rep_nofault.farpool, rep_inert.farpool);
    for q in 0..want.len() {
        assert_eq!(want[q].topk, outs_inert[q].topk, "query {q}: inertness");
    }

    // Seeded far-read faults: failovers fire, recovered queries keep the
    // exact top-k, and the whole faulted timeline is worker-independent.
    let fault =
        fatrq::config::FaultConfig { seed: 77, far_fail_rate: 0.6, ..Default::default() };
    let mut fa = e1.profile_with(e1.params(), &dataset.queries);
    let mut fb = e4.profile_with(e4.params(), &dataset.queries);
    for p in [&mut fa, &mut fb] {
        pool_on(p);
        p.set_fault(fault.clone());
    }
    let (oa, ra) = fa.schedule(8, 0.0);
    let (ob, rb) = fb.schedule(8, 0.0);
    assert!(ra.availability.active);
    assert!(ra.availability.retries > 0, "a 0.6 fail rate over 12 tasks must retry");
    assert!(
        ra.farpool.failovers > 0,
        "replicated ranges must absorb retries by failover rotation"
    );
    assert_eq!(ra.makespan_ns, rb.makespan_ns, "faulted pool clock across workers");
    assert_eq!(ra.farpool, rb.farpool);
    let mut recovered = 0usize;
    for q in 0..oa.len() {
        assert_eq!(oa[q].topk, ob[q].topk, "query {q}: 1 vs 4 workers under faults");
        assert_eq!(ra.timings[q].done_ns, rb.timings[q].done_ns, "query {q}");
        if !ra.timings[q].degrade.is_degraded() {
            recovered += 1;
            assert_eq!(
                oa[q].topk, want[q].topk,
                "query {q} recovered from far faults but lost exactness"
            );
        }
    }
    assert!(recovered > 0, "some queries must recover to full results");
}

#[test]
fn qos_far_shares_keep_every_tenant_completing_and_work_conserving() {
    // The carried-over QoS satellite: tenant weights reach past
    // admission into the far record rotation. The weighted rotation must
    // never starve the light tenant (all queries complete inside the
    // work-conservation bound) and never change results.
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.dataset.queries = 16;
    cfg.sim.stream_interleave = StreamInterleave::Record;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let nq = dataset.num_queries();
    let tags: Vec<usize> = (0..nq).map(|q| q % 2).collect();
    let tenants = vec![
        TenantSpec { name: "heavy".into(), weight: 4.0, quota: 0, trace: None },
        TenantSpec { name: "light".into(), weight: 1.0, quota: 0, trace: None },
    ];

    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    profile.set_tenants(tenants.clone(), tags.clone());
    let m1 = profile.schedule(1, 0.0).1.makespan_ns;
    let (plain_outs, _) = profile.schedule(8, 0.0);
    profile.set_far_qos_shares(true);
    let (outs, rep) = profile.schedule(8, 0.0);

    // Results are a timing concern only; shares never move the top-k.
    for q in 0..nq {
        assert_eq!(outs[q].topk, plain_outs[q].topk, "query {q}: shares moved top-k");
    }
    // Non-starvation: every query (both tenants) completes, and the
    // weighted rotation stays work-conserving against the serialized
    // schedule.
    for (q, t) in rep.timings.iter().enumerate() {
        assert!(t.done_ns > t.admit_ns, "query {q} never completed under QoS shares");
    }
    assert!(
        rep.makespan_ns <= m1 * (1.0 + 1e-9),
        "QoS far shares broke work conservation: {} > {m1}",
        rep.makespan_ns
    );
    assert_eq!(rep.tenants.len(), 2);
    assert_eq!(rep.tenants[0].queries + rep.tenants[1].queries, nq);

    // Determinism across worker counts with shares on.
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let mut p1 = e1.profile_with(e1.params(), &dataset.queries);
    p1.set_tenants(tenants, tags);
    p1.set_far_qos_shares(true);
    let (outs1, rep1) = p1.schedule(8, 0.0);
    assert_eq!(rep.makespan_ns, rep1.makespan_ns, "QoS shares across worker counts");
    for q in 0..nq {
        assert_eq!(outs[q].topk, outs1[q].topk, "query {q}");
        assert_eq!(
            rep.timings[q].done_ns, rep1.timings[q].done_ns,
            "query {q} done (QoS shares)"
        );
    }
}
