//! Integration: the tiered memory manager + device simulators composing
//! the paper's Fig 3 layout, with capacity pressure and access accounting.

use fatrq::config::SimConfig;
use fatrq::simulator::{FarMemoryDevice, SsdSim};
use fatrq::tiering::{Tier, TierCapacities, TieredMemory};

/// Place the paper's layout for a 1M x 768-D corpus and verify tier math.
#[test]
fn paper_layout_fits_and_accounts() {
    let sim = SimConfig::default();
    let mut tm = TieredMemory::new(&sim, TierCapacities::default());
    let n: u64 = 1_000_000;
    // Fast: PQ codes (96 B) + codebooks.
    tm.place("pq_codes", Tier::Fast, n * 96).unwrap();
    tm.place("pq_codebooks", Tier::Fast, 96 * 256 * 8 * 4).unwrap();
    // Far: TRQ records (162 B each, the §V-C number).
    tm.place("trq_records", Tier::Far, n * 162).unwrap();
    // Storage: full vectors (3 KiB each).
    tm.place("vectors", Tier::Storage, n * 768 * 4).unwrap();

    assert!(tm.used(Tier::Fast) < 200 << 20, "fast tier should be ~96 MB");
    assert_eq!(tm.used(Tier::Far), 162_000_000);
    // The paper's storage-efficiency claim: TRQ far-memory footprint is
    // 2.4x smaller than 4-bit SQ residuals (384+8 B) would need.
    let sq4 = n * (384 + 8);
    assert!(
        sq4 as f64 / tm.used(Tier::Far) as f64 > 2.3,
        "storage efficiency {}",
        sq4 as f64 / tm.used(Tier::Far) as f64
    );
}

#[test]
fn capacity_pressure_rejects_overflow() {
    let sim = SimConfig::default();
    // A deliberately tiny far tier: 100 MB.
    let caps = TierCapacities { fast: 1 << 30, far: 100 << 20, storage: 0 };
    let mut tm = TieredMemory::new(&sim, caps);
    // 1M records of 162 B = 162 MB does NOT fit.
    assert!(tm.place("trq", Tier::Far, 162_000_000).is_err());
    // 500k records do.
    tm.place("trq", Tier::Far, 81_000_000).unwrap();
}

#[test]
fn query_access_pattern_cost_ordering() {
    // One refinement round: 320 far reads (162 B) must be far cheaper than
    // 320 SSD reads (3 KB) — the core premise of the paper.
    let sim = SimConfig::default();
    let mut far = FarMemoryDevice::new(&sim);
    let mut far_done = 0.0f64;
    for i in 0..320u64 {
        far_done = far_done.max(far.host_read(i * 162, 162, 0.0));
    }
    let mut ssd = SsdSim::new(&sim);
    let mut ssd_done = 0.0f64;
    for _ in 0..320 {
        ssd_done = ssd_done.max(ssd.read(3072, 0.0));
    }
    assert!(
        far_done * 5.0 < ssd_done,
        "far {far_done:.0} ns !<< ssd {ssd_done:.0} ns"
    );
}

#[test]
fn tier_stats_track_reads() {
    let sim = SimConfig::default();
    let mut tm = TieredMemory::new(&sim, TierCapacities::default());
    tm.place("trq", Tier::Far, 1 << 20).unwrap();
    tm.place("vec", Tier::Storage, 1 << 30).unwrap();
    for i in 0..100u64 {
        tm.read("trq", i * 162, 162, true).unwrap();
    }
    for _ in 0..10 {
        tm.read("vec", 0, 3072, false).unwrap();
    }
    assert_eq!(tm.stats[&Tier::Far].accesses, 100);
    assert_eq!(tm.stats[&Tier::Far].bytes, 16_200);
    assert_eq!(tm.stats[&Tier::Storage].accesses, 10);
    tm.reset_stats();
    assert_eq!(tm.stats[&Tier::Far].accesses, 0);
}

#[test]
fn sequential_trq_layout_beats_random() {
    // The columnar TRQ arena (Fig 3) gives row-buffer locality; random
    // placement of the same records would hit DRAM conflicts.
    let sim = SimConfig::default();
    let mut dev = FarMemoryDevice::new(&sim);
    let seq = dev.stream_records(0, 162, 2000, 0.0, true);
    dev.reset();
    let mut rng = fatrq::util::rng::Rng::new(5);
    let mut rand_done = 0.0f64;
    for _ in 0..2000 {
        let addr = (rng.next_u64() % (1 << 31)) / 162 * 162;
        rand_done = rand_done.max(dev.local_read(addr, 162, 0.0));
    }
    assert!(
        seq < rand_done,
        "sequential {seq:.0} ns !< random {rand_done:.0} ns"
    );
}
