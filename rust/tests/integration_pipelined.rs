//! Pipelined stage-graph serving — end-to-end invariants.
//!
//! - **pipelined-vs-sequential bit-identity**: at every pipeline depth
//!   (1, 4, 16) and for flat/IVF front stages × Baseline/FatrqSw/FatrqHw
//!   (+ early-exit), the pipelined scheduler returns bit-identical top-k
//!   (distance, id) and identical I/O accounting to the sequential
//!   per-query stage walk.
//! - **worker-count determinism**: outcomes, device queueing and the
//!   simulated serving timeline are identical across 1 vs 4 pool workers
//!   (the simulated clock is a pure function of the stage profiles).
//! - **depth-1 == sequential accounting**: one query in flight means
//!   idle devices — zero queueing, query latency = its service total,
//!   makespan = the serialized sum.
//! - **overlap**: at depth ≥ 4 the simulated makespan drops below the
//!   serialized (depth-1) makespan — stage overlap, the point of the
//!   scheduler — while never exceeding it (work conservation).
//! - **open-loop arrivals**: `arrival_qps > 0` spaces arrivals on the
//!   timeline; a bounded depth makes admission wait observable in the
//!   latency percentiles.
//! - **resource-server scheduling** (the unified-scheduler PR):
//!   unbounded CPU lanes reproduce the pre-lane clock bit-for-bit (and a
//!   lane count larger than any concurrency reproduces unbounded
//!   bit-for-bit), bounded lanes only slow things down, Poisson arrivals
//!   are deterministic across worker counts, weighted-fair multi-tenant
//!   admission bounds a flooding tenant's damage to an idle tenant
//!   (isolation), quotas cap per-tenant concurrency, low-weight tenants
//!   never starve, and record-level stream interleaving keeps the depth-1
//!   and work-conservation contracts.

use fatrq::config::{
    ArrivalDist, DatasetConfig, IndexConfig, IndexKind, LanePolicy, QuantConfig, RefineConfig,
    RefineMode, StreamInterleave, SystemConfig, TenantSpec,
};
use fatrq::coordinator::{build_system_with, Pipeline, QueryEngine, QueryParams, ShardedEngine};
use fatrq::vecstore::synthesize;
use std::sync::Arc;

fn cfg(kind: IndexKind) -> SystemConfig {
    let mut cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 32,
            count: 1600,
            clusters: 12,
            noise: 0.3,
            query_noise: 0.8,
            queries: 10,
            seed: 23,
        },
        quant: QuantConfig { pq_m: 8, pq_nbits: 5, kmeans_iters: 6, train_sample: 1200 },
        index: IndexConfig { kind, nlist: 16, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.02,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.sim.shared_timeline = true;
    cfg
}

#[test]
fn pipelined_topk_bit_identical_to_sequential_across_depths() {
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
        let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
        let mode_cases = [
            (RefineMode::Baseline, false),
            (RefineMode::FatrqSw, false),
            (RefineMode::FatrqHw, false),
            (RefineMode::FatrqHw, true),
        ];
        for (mode, early_exit) in mode_cases {
            let params =
                QueryParams::from_config(&cfg).with_mode(mode).with_early_exit(early_exit);
            // Sequential reference: the per-query stage walk on one
            // caller thread, fresh scratch per query.
            let pipeline =
                Pipeline::new(&sys).with_mode(mode).with_early_exit(early_exit);
            let seq: Vec<_> = (0..dataset.num_queries())
                .map(|q| pipeline.query(dataset.query(q)))
                .collect();
            let profile = engine.profile_with(&params, &dataset.queries);
            // The run-to-completion executor walks every task through all
            // its stages in a single dispatch round — the per-stage
            // re-dispatch scheme spun each task through the pool queue
            // once per stage (~4 × ceil(nq / slots) waves).
            assert_eq!(
                profile.waves(),
                1,
                "{}/{mode:?}: stage-graph dispatch-round count regressed",
                kind.name()
            );
            for depth in [1usize, 4, 16] {
                let (outs, _report) = profile.schedule(depth, 0.0);
                assert_eq!(outs.len(), seq.len());
                for (q, (got, want)) in outs.iter().zip(&seq).enumerate() {
                    assert_eq!(
                        got.topk, want.topk,
                        "{}/{mode:?}/ee={early_exit}: query {q} diverged at depth {depth}",
                        kind.name()
                    );
                    assert_eq!(got.breakdown.far_reads, want.breakdown.far_reads);
                    assert_eq!(got.breakdown.ssd_reads, want.breakdown.ssd_reads);
                    assert_eq!(got.breakdown.far_ns, want.breakdown.far_ns);
                    assert_eq!(got.breakdown.ssd_ns, want.breakdown.ssd_ns);
                }
            }
        }
    }
}

#[test]
fn depth_one_is_the_sequential_engine() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let profile = engine.profile_with(engine.params(), &dataset.queries);
    let (outs, report) = profile.schedule(1, 0.0);

    // One query in flight ⇒ every device admission sees an idle device.
    for (q, out) in outs.iter().enumerate() {
        assert_eq!(out.breakdown.queue_ns, 0.0, "query {q} queued at depth 1");
    }
    // Query latency = its simulated service total; makespan = the
    // serialized sum of services.
    let eps = 1e-9;
    for (q, t) in report.timings.iter().enumerate() {
        let lat = t.done_ns - t.admit_ns;
        assert!(t.service_ns > 0.0, "query {q}: empty service total");
        assert!(
            (lat - t.service_ns).abs() <= eps * t.service_ns.max(1.0),
            "query {q}: pipelined latency {lat} != service {}",
            t.service_ns
        );
        assert_eq!(t.arrival_ns, 0.0);
        assert!(t.admit_ns >= t.arrival_ns);
    }
    let serialized: f64 = report.timings.iter().map(|t| t.service_ns).sum();
    assert!(
        (report.makespan_ns - serialized).abs() <= eps * serialized,
        "depth-1 makespan {} != serialized sum {serialized}",
        report.makespan_ns
    );
}

#[test]
fn deeper_pipelines_overlap_and_stay_work_conserving() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    // One functional pass, many schedules: makespans compare identical
    // stage profiles.
    let profile = engine.profile_with(engine.params(), &dataset.queries);
    let m1 = profile.schedule(1, 0.0).1.makespan_ns;
    let m4 = profile.schedule(4, 0.0).1.makespan_ns;
    let m16 = profile.schedule(16, 0.0).1.makespan_ns;
    let m0 = profile.schedule(0, 0.0).1.makespan_ns;
    assert!(
        m4 < m1,
        "depth 4 must overlap stages: makespan {m4} !< sequential {m1}"
    );
    // Work conservation: pipelining can redistribute waiting but never
    // exceed the fully serialized schedule.
    let bound = m1 * (1.0 + 1e-9);
    assert!(m16 <= bound, "depth 16 makespan {m16} above serialized {m1}");
    assert!(m0 <= bound, "unbounded makespan {m0} above serialized {m1}");
    // Device queueing appears once streams overlap.
    let queued: f64 = profile
        .schedule(0, 0.0)
        .0
        .iter()
        .map(|o| o.breakdown.queue_ns)
        .sum();
    assert!(queued > 0.0, "overlapping streams must contend on the shared device");
}

#[test]
fn pipelined_results_independent_of_worker_count() {
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.refine.early_exit = true;
    cfg.serve.pipeline_depth = 4;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let (a, ra) = e1.run_serve(e1.params(), &dataset.queries);
    let (b, rb) = e4.run_serve(e4.params(), &dataset.queries);
    // Warm scratches: a second run must not drift either.
    let (c, rc) = e4.run_serve(e4.params(), &dataset.queries);
    assert_eq!(a.len(), b.len());
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}: 1 vs 4 workers");
        assert_eq!(b[q].topk, c[q].topk, "query {q}: fresh vs warm scratch");
        assert_eq!(a[q].breakdown.far_reads, b[q].breakdown.far_reads, "query {q}");
        assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "query {q}");
        assert_eq!(a[q].breakdown.far_ns, b[q].breakdown.far_ns, "query {q}");
        // The entire simulated serving timeline is a pure function of the
        // functional results — bit-identical across worker counts and
        // repeated runs, admission instants and completions included.
        for (x, y) in [(&ra, &rb), (&rb, &rc)] {
            assert_eq!(x.timings[q].arrival_ns, y.timings[q].arrival_ns, "query {q}");
            assert_eq!(x.timings[q].admit_ns, y.timings[q].admit_ns, "query {q}");
            assert_eq!(x.timings[q].done_ns, y.timings[q].done_ns, "query {q}");
            assert_eq!(x.timings[q].service_ns, y.timings[q].service_ns, "query {q}");
        }
    }
    assert_eq!(ra.makespan_ns, rb.makespan_ns);
    assert_eq!(rb.makespan_ns, rc.makespan_ns);
    assert_eq!(ra.p99_ns, rb.p99_ns);
}

#[test]
fn open_loop_arrivals_space_queries_and_bound_admission() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let profile = engine.profile_with(engine.params(), &dataset.queries);

    // Gentle load, unbounded depth: every query admitted at its arrival.
    let (_, relaxed) = profile.schedule(0, 10.0); // 100 ms apart
    let gap = 1e8;
    for (q, t) in relaxed.timings.iter().enumerate() {
        assert_eq!(t.arrival_ns, q as f64 * gap, "query {q} arrival offset");
        assert_eq!(t.admit_ns, t.arrival_ns, "query {q} should not wait at depth 0");
        assert!(t.done_ns > t.admit_ns);
    }
    assert!(relaxed.makespan_ns >= (relaxed.timings.len() - 1) as f64 * gap);

    // Crushing load, depth 1: arrivals outpace service, so admission
    // waits stack up and the tail grows.
    let (_, crushed) = profile.schedule(1, 1e9); // 1 ns apart
    let mut waited = 0usize;
    for t in &crushed.timings {
        assert!(t.admit_ns >= t.arrival_ns);
        if t.admit_ns > t.arrival_ns {
            waited += 1;
        }
        let lat = t.done_ns - t.arrival_ns;
        assert!(lat > 0.0);
    }
    assert!(
        waited >= crushed.timings.len() - 1,
        "at 1 ns spacing and depth 1, every later query must wait for admission"
    );
    assert!(crushed.p99_ns >= crushed.p50_ns);
    assert!(
        crushed.p99_ns > relaxed.p99_ns,
        "overload tail {} must exceed the relaxed tail {}",
        crushed.p99_ns,
        relaxed.p99_ns
    );
    assert!(crushed.mean_latency_ns > relaxed.mean_latency_ns);
}

#[test]
fn sharded_pipelined_depths_are_bit_identical_and_deterministic() {
    let mut cfg = cfg(IndexKind::Ivf);
    // Deep candidates relative to each shard keep the merge unambiguous.
    cfg.refine.candidates = 300;
    cfg.refine.filter_ratio = 1.0;
    let dataset = synthesize(&cfg.dataset);
    // One shard build, swept over depths (shard builds are not
    // bit-reproducible, so all comparisons share the build).
    let mut engine = ShardedEngine::from_dataset_with_threads(&cfg, &dataset, 4, 2).unwrap();
    engine.set_pipeline_depth(0);
    let unbounded = engine.run(&dataset.queries);
    engine.set_pipeline_depth(1);
    let params = *engine.params();
    let (serial, serial_report) = engine.run_serve(&params, &dataset.queries);
    engine.set_pipeline_depth(4);
    let windowed = engine.run(&dataset.queries);
    for q in 0..unbounded.len() {
        assert_eq!(unbounded[q].topk, serial[q].topk, "query {q}: depth 0 vs 1");
        assert_eq!(serial[q].topk, windowed[q].topk, "query {q}: depth 1 vs 4");
    }
    // At depth 1 only one *query* is in flight, but its 4 shard streams
    // still fan onto the one far-memory device together — so a small
    // queue term is the honest answer (the PR-3 contract), and the
    // timeline latency is its service plus that critical-path queueing,
    // never less.
    for (q, t) in serial_report.timings.iter().enumerate() {
        let lat = t.done_ns - t.admit_ns;
        assert!(serial[q].breakdown.queue_ns >= 0.0);
        assert!(
            lat + 1e-6 >= t.service_ns,
            "query {q}: depth-1 latency {lat} below its service {}",
            t.service_ns
        );
        // The slowest shard's far stage is on the service path.
        assert!(
            lat >= serial[q].breakdown.far_ns,
            "query {q}: timeline latency {lat} below its far stage"
        );
    }
}

// ---------------------------------------------------------------------
// Unified resource-server scheduling: CPU lanes, arrivals, QoS,
// record-level interleaving.
// ---------------------------------------------------------------------

/// `cfg` with a larger query set (the QoS/arrival tests need enough
/// queries for meaningful per-tenant percentiles).
fn cfg_queries(kind: IndexKind, queries: usize) -> SystemConfig {
    let mut cfg = cfg(kind);
    cfg.dataset.queries = queries;
    cfg
}

#[test]
fn unbounded_lanes_reproduce_prelane_clock_bit_for_bit() {
    // The acceptance contract: cpu_lanes = ∞ (0) + uniform arrivals + a
    // single tenant is the PR-4 serving timeline, and a finite lane
    // count larger than any possible compute concurrency reproduces the
    // unbounded clock bit-for-bit — queue_ns, makespan and per-query
    // done times included — across flat/IVF × all refine modes × depths.
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
        let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
        let nq = dataset.num_queries();
        for (mode, early_exit) in [
            (RefineMode::Baseline, false),
            (RefineMode::FatrqSw, false),
            (RefineMode::FatrqHw, false),
            (RefineMode::FatrqHw, true),
        ] {
            let params =
                QueryParams::from_config(&cfg).with_mode(mode).with_early_exit(early_exit);
            let mut profile = engine.profile_with(&params, &dataset.queries);
            for depth in [1usize, 4, 16] {
                profile.set_cpu_lanes(0);
                let (outs_inf, rep_inf) = profile.schedule(depth, 0.0);
                // More lanes than in-flight compute stages can ever
                // exist: the bounded server must never queue, so the
                // clock must match unbounded exactly.
                profile.set_cpu_lanes(nq + 8);
                let (outs_big, rep_big) = profile.schedule(depth, 0.0);
                let tag = format!("{}/{mode:?}/ee={early_exit}/depth={depth}", kind.name());
                assert_eq!(rep_inf.makespan_ns, rep_big.makespan_ns, "{tag}: makespan");
                for q in 0..nq {
                    assert_eq!(outs_inf[q].topk, outs_big[q].topk, "{tag}: query {q}");
                    assert_eq!(
                        outs_inf[q].breakdown.queue_ns, outs_big[q].breakdown.queue_ns,
                        "{tag}: query {q} queue"
                    );
                    assert_eq!(
                        rep_inf.timings[q].admit_ns, rep_big.timings[q].admit_ns,
                        "{tag}: query {q} admit"
                    );
                    assert_eq!(
                        rep_inf.timings[q].done_ns, rep_big.timings[q].done_ns,
                        "{tag}: query {q} done"
                    );
                    assert_eq!(
                        rep_inf.timings[q].service_ns, rep_big.timings[q].service_ns,
                        "{tag}: query {q} service"
                    );
                }
            }
        }
    }
}

#[test]
fn bounded_lanes_only_slow_the_clock_and_charge_cpu_queue() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    // SW refinement runs on CPU lanes, so the lane server sees the most
    // compute in this mode.
    let params = QueryParams::from_config(&cfg).with_mode(RefineMode::FatrqSw);
    let mut profile = engine.profile_with(&params, &dataset.queries);

    // Isolate the lane server from device queueing: with private idle
    // devices (shared timeline off), queue_ns is CPU lane wait alone.
    profile.set_shared_timeline(false);
    profile.set_cpu_lanes(0);
    let (outs_inf, rep_inf) = profile.schedule(8, 0.0);
    let m1 = profile.schedule(1, 0.0).1.makespan_ns;
    profile.set_cpu_lanes(1);
    let (outs_one, rep_one) = profile.schedule(8, 0.0);
    profile.set_cpu_lanes(2);
    let (_, rep_two) = profile.schedule(8, 0.0);

    // Functional results are untouched by the lane count.
    for q in 0..outs_inf.len() {
        assert_eq!(outs_inf[q].topk, outs_one[q].topk, "query {q}");
    }
    // Unbounded lanes over private devices never queue; a single lane
    // serializes every compute stage — 8 co-admitted front stages must
    // wait, and the makespan can only grow.
    let queued_inf: f64 = outs_inf.iter().map(|o| o.breakdown.queue_ns).sum();
    let queued_one: f64 = outs_one.iter().map(|o| o.breakdown.queue_ns).sum();
    assert_eq!(queued_inf, 0.0, "unbounded lanes + private devices must not queue");
    assert!(queued_one > 0.0, "a single lane must charge CPU queueing");
    assert!(
        rep_one.makespan_ns >= rep_inf.makespan_ns,
        "1 lane made the clock faster: {} < {}",
        rep_one.makespan_ns,
        rep_inf.makespan_ns
    );
    assert!(
        rep_two.makespan_ns <= rep_one.makespan_ns * (1.0 + 1e-9),
        "2 lanes slower than 1 lane"
    );
    // Work conservation survives lane bounding: never worse than the
    // fully serialized schedule.
    assert!(
        rep_one.makespan_ns <= m1 * (1.0 + 1e-9),
        "1-lane depth-8 makespan {} above serialized {m1}",
        rep_one.makespan_ns
    );
    // And with the shared devices back on, bounding lanes still never
    // breaks work conservation.
    profile.set_shared_timeline(true);
    profile.set_cpu_lanes(0);
    let shared_m1 = profile.schedule(1, 0.0).1.makespan_ns;
    profile.set_cpu_lanes(1);
    let (_, rep_shared_one) = profile.schedule(8, 0.0);
    assert!(
        rep_shared_one.makespan_ns <= shared_m1 * (1.0 + 1e-9),
        "shared-device 1-lane makespan {} above serialized {shared_m1}",
        rep_shared_one.makespan_ns
    );
    // Depth 1 with a single lane is still the sequential engine: one
    // query in flight has at most one compute stage at a time.
    profile.set_cpu_lanes(1);
    let (outs_d1, rep_d1) = profile.schedule(1, 0.0);
    for (q, out) in outs_d1.iter().enumerate() {
        assert_eq!(out.breakdown.queue_ns, 0.0, "query {q} queued at depth 1 / 1 lane");
        let t = rep_d1.timings[q];
        let lat = t.done_ns - t.admit_ns;
        assert!(
            (lat - t.service_ns).abs() <= 1e-9 * t.service_ns.max(1.0),
            "query {q}: depth-1 latency {lat} != service {}",
            t.service_ns
        );
    }
}

#[test]
fn poisson_arrivals_are_deterministic_and_differ_from_uniform() {
    let mut cfg = cfg_queries(IndexKind::Ivf, 16);
    cfg.sim.arrival_dist = ArrivalDist::Poisson;
    cfg.sim.arrival_seed = 7;
    cfg.sim.arrival_qps = 50_000.0; // 20 us mean gap: well into overload
    cfg.serve.pipeline_depth = 4;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());

    // Worker-count determinism: the Poisson gap sequence lives in the
    // pure simulated clock, so the entire timeline is identical across
    // pool sizes and repeated runs.
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let (a, ra) = e1.run_serve(e1.params(), &dataset.queries);
    let (b, rb) = e4.run_serve(e4.params(), &dataset.queries);
    let (_, rc) = e4.run_serve(e4.params(), &dataset.queries);
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}");
        assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "query {q}");
        for (x, y) in [(&ra, &rb), (&rb, &rc)] {
            assert_eq!(x.timings[q].arrival_ns, y.timings[q].arrival_ns, "query {q}");
            assert_eq!(x.timings[q].admit_ns, y.timings[q].admit_ns, "query {q}");
            assert_eq!(x.timings[q].done_ns, y.timings[q].done_ns, "query {q}");
        }
    }
    assert_eq!(ra.makespan_ns, rb.makespan_ns);
    assert_eq!(ra.p99_ns, rb.p99_ns);

    // Arrivals are genuinely exponential-gapped: non-decreasing, start
    // at 0, and differ from the uniform grid at the same rate.
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    let (_, poisson) = profile.schedule(4, 50_000.0);
    profile.set_arrival_dist(ArrivalDist::Uniform);
    let (_, uniform) = profile.schedule(4, 50_000.0);
    assert_eq!(poisson.timings[0].arrival_ns, 0.0);
    let mut diverged = false;
    let mut prev = 0.0f64;
    for q in 0..poisson.timings.len() {
        let at = poisson.timings[q].arrival_ns;
        assert!(at >= prev, "Poisson arrivals must be non-decreasing");
        prev = at;
        if at != uniform.timings[q].arrival_ns {
            diverged = true;
        }
    }
    assert!(diverged, "Poisson arrivals collapsed onto the uniform grid");
}

#[test]
fn arrival_trace_replays_and_tiles() {
    let cfg = cfg_queries(IndexKind::Ivf, 10);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    // 4-entry trace for 10 queries: entries repeat shifted by the span.
    profile.set_arrival_trace(vec![0.0, 100.0, 250.0, 1000.0]);
    let (_, rep) = profile.schedule(0, 0.0);
    let want = [
        0.0, 100.0, 250.0, 1000.0, // first pass
        1000.0, 1100.0, 1250.0, 2000.0, // tiled by span 1000
        2000.0, 2100.0,
    ];
    for (q, &w) in want.iter().enumerate() {
        assert_eq!(rep.timings[q].arrival_ns, w, "query {q} trace arrival");
    }
}

#[test]
fn weighted_fair_tenants_isolate_a_flooded_batch_from_a_light_tenant() {
    let cfg = cfg_queries(IndexKind::Ivf, 24);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    let nq = dataset.num_queries();
    let (nflood, nlight) = (20usize, 4usize);
    assert_eq!(nflood + nlight, nq);
    // Tenant 0 floods 20 queries at t = 0; tenant 1 trickles 4 queries
    // in while the flood is still draining.
    let tags: Vec<usize> = (0..nq).map(|q| usize::from(q >= nflood)).collect();
    let m1 = profile.schedule(1, 0.0).1.makespan_ns;
    let mut trace = vec![0.0; nflood];
    for i in 0..nlight {
        trace.push(m1 * 0.1 * (i + 1) as f64 / nlight as f64);
    }
    profile.set_arrival_trace(trace);

    // FIFO baseline (no tenants configured): the light queries sit
    // behind the whole flood backlog.
    let (_, fifo) = profile.schedule(2, 0.0);
    let light_max = |rep: &fatrq::coordinator::ServeReport| {
        rep.timings[nflood..].iter().map(|t| t.latency_ns()).fold(0.0f64, f64::max)
    };
    let fifo_light = light_max(&fifo);

    // Weighted-fair admission: the light tenant's counter stays minimal,
    // so each of its queries wins the next freed slot.
    profile.set_tenants(
        vec![
            TenantSpec { name: "flood".into(), weight: 1.0, quota: 0, trace: None },
            TenantSpec { name: "latency".into(), weight: 8.0, quota: 0, trace: None },
        ],
        tags,
    );
    let (_, wfq) = profile.schedule(2, 0.0);

    // Per-tenant percentiles are reported.
    assert_eq!(wfq.tenants.len(), 2);
    assert_eq!(wfq.tenants[0].name, "flood");
    assert_eq!(wfq.tenants[0].queries, nflood);
    assert_eq!(wfq.tenants[1].queries, nlight);
    assert!(wfq.tenants[1].p99_ns <= wfq.tenants[0].p99_ns);

    // The isolation bound, runtime-asserted: a light query waits at most
    // one in-flight query turn (the longest admit→done latency in the
    // batch) per concurrently-waiting light query — its own tenant's
    // queue, never the flood's ~20-query backlog (which is what the FIFO
    // schedule below charges it).
    let max_turn = wfq
        .timings
        .iter()
        .map(|t| t.done_ns - t.admit_ns)
        .fold(0.0f64, f64::max);
    for (i, t) in wfq.timings[nflood..].iter().enumerate() {
        let wait = t.admit_ns - t.arrival_ns;
        assert!(
            wait <= nlight as f64 * max_turn + 1.0,
            "light query {i}: admission wait {wait} exceeds {nlight} slot turns {max_turn} \
             — the flood backlog leaked in front of the light tenant"
        );
    }
    // And it is a real improvement over FIFO.
    let wfq_light = light_max(&wfq);
    assert!(
        wfq_light < fifo_light,
        "weighted-fair light tail {wfq_light} !< FIFO light tail {fifo_light}"
    );
}

#[test]
fn tenant_quota_caps_inflight_concurrency() {
    let cfg = cfg_queries(IndexKind::Ivf, 16);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    let nq = dataset.num_queries();
    // All queries belong to one quota-1 tenant; a second (empty) tenant
    // exists so the schedule is genuinely multi-tenant.
    profile.set_tenants(
        vec![
            TenantSpec { name: "capped".into(), weight: 1.0, quota: 1, trace: None },
            TenantSpec { name: "other".into(), weight: 1.0, quota: 0, trace: None },
        ],
        vec![0; nq],
    );
    let (_, rep) = profile.schedule(8, 0.0);
    // Quota 1 means no two of the tenant's queries are ever in flight
    // together, even though the depth-8 window has room.
    let mut spans: Vec<(f64, f64)> =
        rep.timings.iter().map(|t| (t.admit_ns, t.done_ns)).collect();
    spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for w in spans.windows(2) {
        assert!(
            w[1].0 >= w[0].1 - 1e-6,
            "quota-1 tenant overlapped in flight: {:?} then {:?}",
            w[0],
            w[1]
        );
    }
    assert_eq!(rep.tenants[0].queries, nq);
    assert_eq!(rep.tenants[1].queries, 0);
}

#[test]
fn weighted_fair_admission_never_starves_low_weight_tenants() {
    let cfg = cfg_queries(IndexKind::Ivf, 24);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    let nq = dataset.num_queries();
    // Both tenants flood at t = 0; tenant heavy has 8x the weight.
    let tags: Vec<usize> = (0..nq).map(|q| q % 2).collect();
    profile.set_tenants(
        vec![
            TenantSpec { name: "heavy".into(), weight: 8.0, quota: 0, trace: None },
            TenantSpec { name: "low".into(), weight: 1.0, quota: 0, trace: None },
        ],
        tags.clone(),
    );
    let (_, rep) = profile.schedule(2, 0.0);
    // Every low-weight query completes...
    for (q, t) in rep.timings.iter().enumerate() {
        assert!(t.done_ns > t.admit_ns, "query {q} never completed");
    }
    // ...and the low-weight tenant is admitted long before the heavy
    // tenant drains — weighted sharing, not starvation.
    let low_first = rep
        .timings
        .iter()
        .enumerate()
        .filter(|(q, _)| tags[*q] == 1)
        .map(|(_, t)| t.admit_ns)
        .fold(f64::INFINITY, f64::min);
    let heavy_last = rep
        .timings
        .iter()
        .enumerate()
        .filter(|(q, _)| tags[*q] == 0)
        .map(|(_, t)| t.admit_ns)
        .fold(0.0f64, f64::max);
    assert!(
        low_first < heavy_last,
        "low-weight tenant starved: first admit {low_first} after heavy drain {heavy_last}"
    );
    // Weighted shares show up in the tails: the heavy tenant's queries
    // wait less on average.
    assert!(rep.tenants[0].mean_latency_ns <= rep.tenants[1].mean_latency_ns);
}

#[test]
fn record_interleave_keeps_depth1_identity_and_work_conservation() {
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.sim.stream_interleave = StreamInterleave::Record;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);

    // Depth 1 in record mode: streams never co-exist on the device, so
    // the sequential contract holds exactly.
    let (outs_r1, rep_r1) = profile.schedule(1, 0.0);
    for (q, out) in outs_r1.iter().enumerate() {
        assert_eq!(out.breakdown.queue_ns, 0.0, "query {q} queued at depth 1 (record)");
    }
    // ...and matches the burst discipline bit-for-bit at depth 1.
    profile.set_stream_interleave(StreamInterleave::Burst);
    let (outs_b1, rep_b1) = profile.schedule(1, 0.0);
    assert_eq!(rep_r1.makespan_ns, rep_b1.makespan_ns, "depth-1 record != burst");
    for q in 0..outs_r1.len() {
        assert_eq!(outs_r1[q].topk, outs_b1[q].topk, "query {q}");
        assert_eq!(
            rep_r1.timings[q].done_ns, rep_b1.timings[q].done_ns,
            "query {q} done (record vs burst at depth 1)"
        );
    }

    // Deep pipeline in record mode: functional identity, overlap, work
    // conservation, and contention still observed.
    profile.set_stream_interleave(StreamInterleave::Record);
    let (outs_r16, rep_r16) = profile.schedule(16, 0.0);
    for q in 0..outs_r16.len() {
        assert_eq!(outs_r16[q].topk, outs_b1[q].topk, "query {q} (record depth 16)");
    }
    let m1 = rep_r1.makespan_ns;
    assert!(
        rep_r16.makespan_ns < m1,
        "record-mode depth 16 must overlap: {} !< {m1}",
        rep_r16.makespan_ns
    );
    assert!(
        rep_r16.makespan_ns <= m1 * (1.0 + 1e-9),
        "record-mode work conservation violated"
    );
    let queued: f64 = outs_r16.iter().map(|o| o.breakdown.queue_ns).sum();
    assert!(queued > 0.0, "overlapping record-mode streams must still contend");
}

#[test]
fn fcfs_lane_policy_is_the_default_and_bit_identical() {
    // FCFS is the shipped default; setting it explicitly — or enabling
    // SSF with unbounded lanes, where reordering a queue that never
    // forms is meaningless — must reproduce the untouched clock
    // bit-for-bit at every depth.
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let params = QueryParams::from_config(&cfg).with_mode(RefineMode::FatrqSw);
    let base = engine.profile_with(&params, &dataset.queries);
    let mut explicit = engine.profile_with(&params, &dataset.queries);
    explicit.set_lane_policy(LanePolicy::Fcfs);
    let mut ssf_unbounded = engine.profile_with(&params, &dataset.queries);
    ssf_unbounded.set_lane_policy(LanePolicy::Ssf);
    ssf_unbounded.set_cpu_lanes(0);
    for depth in [1usize, 8] {
        let (a, ra) = base.schedule(depth, 0.0);
        let (b, rb) = explicit.schedule(depth, 0.0);
        let (c, rc) = ssf_unbounded.schedule(depth, 0.0);
        assert_eq!(ra.makespan_ns, rb.makespan_ns, "depth {depth}: explicit fcfs");
        assert_eq!(ra.makespan_ns, rc.makespan_ns, "depth {depth}: ssf w/o lanes");
        for q in 0..a.len() {
            assert_eq!(a[q].topk, b[q].topk, "depth {depth}: query {q}");
            assert_eq!(a[q].topk, c[q].topk, "depth {depth}: query {q}");
            assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "{depth}/{q}");
            assert_eq!(a[q].breakdown.queue_ns, c[q].breakdown.queue_ns, "{depth}/{q}");
            assert_eq!(ra.timings[q].done_ns, rb.timings[q].done_ns, "{depth}/{q}");
            assert_eq!(ra.timings[q].done_ns, rc.timings[q].done_ns, "{depth}/{q}");
        }
    }
}

#[test]
fn ssf_lane_policy_is_deterministic_and_work_conserving() {
    let cfg = cfg_queries(IndexKind::Ivf, 16);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    // SW refinement is the most lane-hungry mode: shortest-first has
    // real choices to make when a single lane serializes it.
    let params = QueryParams::from_config(&cfg).with_mode(RefineMode::FatrqSw);
    let mut fcfs = e4.profile_with(&params, &dataset.queries);
    fcfs.set_cpu_lanes(1);
    let mut s1 = e1.profile_with(&params, &dataset.queries);
    let mut s4 = e4.profile_with(&params, &dataset.queries);
    for p in [&mut s1, &mut s4] {
        p.set_cpu_lanes(1);
        p.set_lane_policy(LanePolicy::Ssf);
    }
    let (f_outs, f_rep) = fcfs.schedule(8, 0.0);
    let (a, ra) = s1.schedule(8, 0.0);
    let (b, rb) = s4.schedule(8, 0.0);
    for q in 0..a.len() {
        // Admission order is a timing concern only.
        assert_eq!(f_outs[q].topk, a[q].topk, "query {q}: fcfs vs ssf");
        assert_eq!(a[q].topk, b[q].topk, "query {q}: 1 vs 4 workers");
        assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "query {q}");
        assert_eq!(ra.timings[q].admit_ns, rb.timings[q].admit_ns, "query {q}");
        assert_eq!(ra.timings[q].done_ns, rb.timings[q].done_ns, "query {q}");
        assert_eq!(ra.timings[q].service_ns, rb.timings[q].service_ns, "query {q}");
    }
    assert_eq!(ra.makespan_ns, rb.makespan_ns, "ssf across worker counts");
    assert_eq!(ra.p99_ns, rb.p99_ns);
    // Work conservation survives the reorder: never worse than the
    // fully serialized schedule, and shortest-first should not hurt the
    // mean at a contended single lane (a loose guard, not a theorem —
    // SSF trades tail for mean).
    let m1 = s4.schedule(1, 0.0).1.makespan_ns;
    assert!(
        ra.makespan_ns <= m1 * (1.0 + 1e-9),
        "ssf depth-8 makespan {} above serialized {m1}",
        ra.makespan_ns
    );
    assert!(
        ra.mean_latency_ns <= f_rep.mean_latency_ns * 1.10,
        "ssf mean {} well above fcfs mean {}",
        ra.mean_latency_ns,
        f_rep.mean_latency_ns
    );
    // Depth 1 leaves one stage in flight at a time: nothing to reorder,
    // so SSF must reproduce FCFS bit-for-bit.
    let (fd1, frd1) = fcfs.schedule(1, 0.0);
    let (sd1, srd1) = s4.schedule(1, 0.0);
    assert_eq!(frd1.makespan_ns, srd1.makespan_ns, "depth-1 ssf == fcfs");
    for q in 0..fd1.len() {
        assert_eq!(fd1[q].breakdown.queue_ns, sd1[q].breakdown.queue_ns, "query {q}");
        assert_eq!(frd1.timings[q].done_ns, srd1.timings[q].done_ns, "query {q}");
    }
}
