//! Pipelined stage-graph serving — end-to-end invariants.
//!
//! - **pipelined-vs-sequential bit-identity**: at every pipeline depth
//!   (1, 4, 16) and for flat/IVF front stages × Baseline/FatrqSw/FatrqHw
//!   (+ early-exit), the pipelined scheduler returns bit-identical top-k
//!   (distance, id) and identical I/O accounting to the sequential
//!   per-query stage walk.
//! - **worker-count determinism**: outcomes, device queueing and the
//!   simulated serving timeline are identical across 1 vs 4 pool workers
//!   (the simulated clock is a pure function of the stage profiles).
//! - **depth-1 == sequential accounting**: one query in flight means
//!   idle devices — zero queueing, query latency = its service total,
//!   makespan = the serialized sum.
//! - **overlap**: at depth ≥ 4 the simulated makespan drops below the
//!   serialized (depth-1) makespan — stage overlap, the point of the
//!   scheduler — while never exceeding it (work conservation).
//! - **open-loop arrivals**: `arrival_qps > 0` spaces arrivals on the
//!   timeline; a bounded depth makes admission wait observable in the
//!   latency percentiles.

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system_with, Pipeline, QueryEngine, QueryParams, ShardedEngine};
use fatrq::vecstore::synthesize;
use std::sync::Arc;

fn cfg(kind: IndexKind) -> SystemConfig {
    let mut cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 32,
            count: 1600,
            clusters: 12,
            noise: 0.3,
            query_noise: 0.8,
            queries: 10,
            seed: 23,
        },
        quant: QuantConfig { pq_m: 8, pq_nbits: 5, kmeans_iters: 6, train_sample: 1200 },
        index: IndexConfig { kind, nlist: 16, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.02,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.sim.shared_timeline = true;
    cfg
}

#[test]
fn pipelined_topk_bit_identical_to_sequential_across_depths() {
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
        let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
        let mode_cases = [
            (RefineMode::Baseline, false),
            (RefineMode::FatrqSw, false),
            (RefineMode::FatrqHw, false),
            (RefineMode::FatrqHw, true),
        ];
        for (mode, early_exit) in mode_cases {
            let params =
                QueryParams::from_config(&cfg).with_mode(mode).with_early_exit(early_exit);
            // Sequential reference: the per-query stage walk on one
            // caller thread, fresh scratch per query.
            let pipeline =
                Pipeline::new(&sys).with_mode(mode).with_early_exit(early_exit);
            let seq: Vec<_> = (0..dataset.num_queries())
                .map(|q| pipeline.query(dataset.query(q)))
                .collect();
            let profile = engine.profile_with(&params, &dataset.queries);
            for depth in [1usize, 4, 16] {
                let (outs, _report) = profile.schedule(depth, 0.0);
                assert_eq!(outs.len(), seq.len());
                for (q, (got, want)) in outs.iter().zip(&seq).enumerate() {
                    assert_eq!(
                        got.topk, want.topk,
                        "{}/{mode:?}/ee={early_exit}: query {q} diverged at depth {depth}",
                        kind.name()
                    );
                    assert_eq!(got.breakdown.far_reads, want.breakdown.far_reads);
                    assert_eq!(got.breakdown.ssd_reads, want.breakdown.ssd_reads);
                    assert_eq!(got.breakdown.far_ns, want.breakdown.far_ns);
                    assert_eq!(got.breakdown.ssd_ns, want.breakdown.ssd_ns);
                }
            }
        }
    }
}

#[test]
fn depth_one_is_the_sequential_engine() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let profile = engine.profile_with(engine.params(), &dataset.queries);
    let (outs, report) = profile.schedule(1, 0.0);

    // One query in flight ⇒ every device admission sees an idle device.
    for (q, out) in outs.iter().enumerate() {
        assert_eq!(out.breakdown.queue_ns, 0.0, "query {q} queued at depth 1");
    }
    // Query latency = its simulated service total; makespan = the
    // serialized sum of services.
    let eps = 1e-9;
    for (q, t) in report.timings.iter().enumerate() {
        let lat = t.done_ns - t.admit_ns;
        assert!(t.service_ns > 0.0, "query {q}: empty service total");
        assert!(
            (lat - t.service_ns).abs() <= eps * t.service_ns.max(1.0),
            "query {q}: pipelined latency {lat} != service {}",
            t.service_ns
        );
        assert_eq!(t.arrival_ns, 0.0);
        assert!(t.admit_ns >= t.arrival_ns);
    }
    let serialized: f64 = report.timings.iter().map(|t| t.service_ns).sum();
    assert!(
        (report.makespan_ns - serialized).abs() <= eps * serialized,
        "depth-1 makespan {} != serialized sum {serialized}",
        report.makespan_ns
    );
}

#[test]
fn deeper_pipelines_overlap_and_stay_work_conserving() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    // One functional pass, many schedules: makespans compare identical
    // stage profiles.
    let profile = engine.profile_with(engine.params(), &dataset.queries);
    let m1 = profile.schedule(1, 0.0).1.makespan_ns;
    let m4 = profile.schedule(4, 0.0).1.makespan_ns;
    let m16 = profile.schedule(16, 0.0).1.makespan_ns;
    let m0 = profile.schedule(0, 0.0).1.makespan_ns;
    assert!(
        m4 < m1,
        "depth 4 must overlap stages: makespan {m4} !< sequential {m1}"
    );
    // Work conservation: pipelining can redistribute waiting but never
    // exceed the fully serialized schedule.
    let bound = m1 * (1.0 + 1e-9);
    assert!(m16 <= bound, "depth 16 makespan {m16} above serialized {m1}");
    assert!(m0 <= bound, "unbounded makespan {m0} above serialized {m1}");
    // Device queueing appears once streams overlap.
    let queued: f64 = profile
        .schedule(0, 0.0)
        .0
        .iter()
        .map(|o| o.breakdown.queue_ns)
        .sum();
    assert!(queued > 0.0, "overlapping streams must contend on the shared device");
}

#[test]
fn pipelined_results_independent_of_worker_count() {
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.refine.early_exit = true;
    cfg.serve.pipeline_depth = 4;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let (a, ra) = e1.run_serve(e1.params(), &dataset.queries);
    let (b, rb) = e4.run_serve(e4.params(), &dataset.queries);
    // Warm scratches: a second run must not drift either.
    let (c, rc) = e4.run_serve(e4.params(), &dataset.queries);
    assert_eq!(a.len(), b.len());
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}: 1 vs 4 workers");
        assert_eq!(b[q].topk, c[q].topk, "query {q}: fresh vs warm scratch");
        assert_eq!(a[q].breakdown.far_reads, b[q].breakdown.far_reads, "query {q}");
        assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "query {q}");
        assert_eq!(a[q].breakdown.far_ns, b[q].breakdown.far_ns, "query {q}");
        // The entire simulated serving timeline is a pure function of the
        // functional results — bit-identical across worker counts and
        // repeated runs, admission instants and completions included.
        for (x, y) in [(&ra, &rb), (&rb, &rc)] {
            assert_eq!(x.timings[q].arrival_ns, y.timings[q].arrival_ns, "query {q}");
            assert_eq!(x.timings[q].admit_ns, y.timings[q].admit_ns, "query {q}");
            assert_eq!(x.timings[q].done_ns, y.timings[q].done_ns, "query {q}");
            assert_eq!(x.timings[q].service_ns, y.timings[q].service_ns, "query {q}");
        }
    }
    assert_eq!(ra.makespan_ns, rb.makespan_ns);
    assert_eq!(rb.makespan_ns, rc.makespan_ns);
    assert_eq!(ra.p99_ns, rb.p99_ns);
}

#[test]
fn open_loop_arrivals_space_queries_and_bound_admission() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let profile = engine.profile_with(engine.params(), &dataset.queries);

    // Gentle load, unbounded depth: every query admitted at its arrival.
    let (_, relaxed) = profile.schedule(0, 10.0); // 100 ms apart
    let gap = 1e8;
    for (q, t) in relaxed.timings.iter().enumerate() {
        assert_eq!(t.arrival_ns, q as f64 * gap, "query {q} arrival offset");
        assert_eq!(t.admit_ns, t.arrival_ns, "query {q} should not wait at depth 0");
        assert!(t.done_ns > t.admit_ns);
    }
    assert!(relaxed.makespan_ns >= (relaxed.timings.len() - 1) as f64 * gap);

    // Crushing load, depth 1: arrivals outpace service, so admission
    // waits stack up and the tail grows.
    let (_, crushed) = profile.schedule(1, 1e9); // 1 ns apart
    let mut waited = 0usize;
    for t in &crushed.timings {
        assert!(t.admit_ns >= t.arrival_ns);
        if t.admit_ns > t.arrival_ns {
            waited += 1;
        }
        let lat = t.done_ns - t.arrival_ns;
        assert!(lat > 0.0);
    }
    assert!(
        waited >= crushed.timings.len() - 1,
        "at 1 ns spacing and depth 1, every later query must wait for admission"
    );
    assert!(crushed.p99_ns >= crushed.p50_ns);
    assert!(
        crushed.p99_ns > relaxed.p99_ns,
        "overload tail {} must exceed the relaxed tail {}",
        crushed.p99_ns,
        relaxed.p99_ns
    );
    assert!(crushed.mean_latency_ns > relaxed.mean_latency_ns);
}

#[test]
fn sharded_pipelined_depths_are_bit_identical_and_deterministic() {
    let mut cfg = cfg(IndexKind::Ivf);
    // Deep candidates relative to each shard keep the merge unambiguous.
    cfg.refine.candidates = 300;
    cfg.refine.filter_ratio = 1.0;
    let dataset = synthesize(&cfg.dataset);
    // One shard build, swept over depths (shard builds are not
    // bit-reproducible, so all comparisons share the build).
    let mut engine = ShardedEngine::from_dataset_with_threads(&cfg, &dataset, 4, 2).unwrap();
    engine.set_pipeline_depth(0);
    let unbounded = engine.run(&dataset.queries);
    engine.set_pipeline_depth(1);
    let params = *engine.params();
    let (serial, serial_report) = engine.run_serve(&params, &dataset.queries);
    engine.set_pipeline_depth(4);
    let windowed = engine.run(&dataset.queries);
    for q in 0..unbounded.len() {
        assert_eq!(unbounded[q].topk, serial[q].topk, "query {q}: depth 0 vs 1");
        assert_eq!(serial[q].topk, windowed[q].topk, "query {q}: depth 1 vs 4");
    }
    // At depth 1 only one *query* is in flight, but its 4 shard streams
    // still fan onto the one far-memory device together — so a small
    // queue term is the honest answer (the PR-3 contract), and the
    // timeline latency is its service plus that critical-path queueing,
    // never less.
    for (q, t) in serial_report.timings.iter().enumerate() {
        let lat = t.done_ns - t.admit_ns;
        assert!(serial[q].breakdown.queue_ns >= 0.0);
        assert!(
            lat + 1e-6 >= t.service_ns,
            "query {q}: depth-1 latency {lat} below its service {}",
            t.service_ns
        );
        // The slowest shard's far stage is on the service path.
        assert!(
            lat >= serial[q].breakdown.far_ns,
            "query {q}: timeline latency {lat} below its far stage"
        );
    }
}
