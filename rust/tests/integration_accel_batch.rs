//! Batch-coalescing accelerator rerank tier — end-to-end contracts.
//!
//! - **batch-1 bit-identity**: `batch_max = 1` seals every device batch
//!   at its first joiner, so the batch window is structurally inert —
//!   zero window, a huge window, and `batch_max = 8` with a zero window
//!   all produce bit-identical serving timelines. This is the per-query
//!   accelerator baseline every coalescing run is measured against.
//! - **functional invariance**: where the rerank runs (host lanes vs
//!   device batches) never changes the returned top-k — only the clock.
//! - **depth-1 idle accounting**: one query in flight means an idle
//!   transfer link and an idle device — queue_ns stays exactly 0.0.
//! - **worker-count determinism**: the coalesced timeline, including
//!   batch occupancies and both accel queue columns, is a pure function
//!   of the stage profiles — identical across 1 vs 4 pool workers.
//! - **coalescing pays**: under concurrency, larger admission batches
//!   amortize the fixed launch overhead and the makespan drops below
//!   the singleton-launch (batch_max = 1) makespan.
//! - **faults compose**: a zero accel fault rate is structurally inert;
//!   a seeded launch-fault plan retries whole batches deterministically
//!   and degrades every member together once past the retry budget.

use fatrq::config::{
    AccelRerank, DatasetConfig, FaultConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig,
    RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system_with, QueryEngine, QueryParams};
use fatrq::vecstore::synthesize;
use std::sync::Arc;

fn cfg(kind: IndexKind) -> SystemConfig {
    let mut cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 32,
            count: 1600,
            clusters: 12,
            noise: 0.3,
            query_noise: 0.8,
            queries: 10,
            seed: 23,
        },
        quant: QuantConfig { pq_m: 8, pq_nbits: 5, kmeans_iters: 6, train_sample: 1200 },
        index: IndexConfig { kind, nlist: 16, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.02,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.sim.shared_timeline = true;
    cfg
}

fn cfg_queries(kind: IndexKind, queries: usize) -> SystemConfig {
    let mut cfg = cfg(kind);
    cfg.dataset.queries = queries;
    cfg
}

#[test]
fn batch_one_is_bit_identical_regardless_of_window() {
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
        let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
        for (mode, early_exit) in [
            (RefineMode::Baseline, false),
            (RefineMode::FatrqSw, false),
            (RefineMode::FatrqHw, false),
            (RefineMode::FatrqHw, true),
        ] {
            let params =
                QueryParams::from_config(&cfg).with_mode(mode).with_early_exit(early_exit);
            let host = engine.profile_with(&params, &dataset.queries);
            // Three configurations that must collapse to the same
            // singleton-launch timeline: batch_max = 1 seals at the
            // first joiner no matter the window, and a zero window
            // seals at the first joiner no matter the cap.
            let mut dev = [
                engine.profile_with(&params, &dataset.queries),
                engine.profile_with(&params, &dataset.queries),
                engine.profile_with(&params, &dataset.queries),
            ];
            for p in dev.iter_mut() {
                p.set_accel_rerank(AccelRerank::Batch);
            }
            dev[0].set_accel_batch_max(1);
            dev[0].set_accel_batch_window_us(0.0);
            dev[1].set_accel_batch_max(1);
            dev[1].set_accel_batch_window_us(1e6);
            dev[2].set_accel_batch_max(8);
            dev[2].set_accel_batch_window_us(0.0);
            for depth in [1usize, 4, 16] {
                let tag = format!("{}/{mode:?}/ee={early_exit}/depth={depth}", kind.name());
                let (h_outs, _) = host.schedule(depth, 0.0);
                let (a, ra) = dev[0].schedule(depth, 0.0);
                let (b, rb) = dev[1].schedule(depth, 0.0);
                let (c, rc) = dev[2].schedule(depth, 0.0);
                assert!(ra.accel.active, "{tag}: accel tier inactive");
                for q in 0..a.len() {
                    // Moving the rerank onto the device is a timing
                    // change only: the returned top-k never moves.
                    assert_eq!(h_outs[q].topk, a[q].topk, "{tag}: query {q} host vs device");
                    assert_eq!(a[q].topk, b[q].topk, "{tag}: query {q}");
                    assert_eq!(b[q].topk, c[q].topk, "{tag}: query {q}");
                    assert_eq!(
                        a[q].breakdown.queue_ns, b[q].breakdown.queue_ns,
                        "{tag}: query {q} queue"
                    );
                    assert_eq!(
                        b[q].breakdown.queue_ns, c[q].breakdown.queue_ns,
                        "{tag}: query {q} queue"
                    );
                    for (x, y) in [(&ra, &rb), (&rb, &rc)] {
                        assert_eq!(x.timings[q].admit_ns, y.timings[q].admit_ns, "{tag}: {q}");
                        assert_eq!(x.timings[q].done_ns, y.timings[q].done_ns, "{tag}: {q}");
                        assert_eq!(
                            x.timings[q].service_ns, y.timings[q].service_ns,
                            "{tag}: {q}"
                        );
                    }
                }
                assert_eq!(ra.makespan_ns, rb.makespan_ns, "{tag}: makespan");
                assert_eq!(rb.makespan_ns, rc.makespan_ns, "{tag}: makespan");
                assert_eq!(ra.p99_ns, rb.p99_ns, "{tag}: p99");
                for r in [&ra, &rb, &rc] {
                    assert!(r.accel.max_batch <= 1, "{tag}: coalesced under batch-1 rules");
                    if r.accel.batches > 0 {
                        assert_eq!(r.accel.mean_batch(), 1.0, "{tag}: singleton launches");
                    }
                }
            }
        }
    }
}

#[test]
fn depth_one_device_path_never_queues() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    profile.set_accel_rerank(AccelRerank::Batch);
    profile.set_accel_batch_max(1);
    profile.set_accel_batch_window_us(0.0);
    let (outs, rep) = profile.schedule(1, 0.0);
    assert!(rep.accel.active);
    assert!(rep.accel.tasks > 0, "the rerank stage must reach the device");
    // One query in flight: the transfer link and the device are always
    // idle at admission, so the carved-out per-member accounting must
    // report exactly zero wait — not an ulp-sized residue.
    assert_eq!(rep.accel.xfer_queue_ns, 0.0, "idle link must not queue");
    assert_eq!(rep.accel.accel_queue_ns, 0.0, "idle device must not queue");
    for (q, out) in outs.iter().enumerate() {
        assert_eq!(out.breakdown.queue_ns, 0.0, "query {q} queued at depth 1");
        assert!(out.breakdown.accel_batch <= 1, "query {q} batch occupancy");
        let t = rep.timings[q];
        let lat = t.done_ns - t.admit_ns;
        assert!(
            (lat - t.service_ns).abs() <= 1e-9 * t.service_ns.max(1.0),
            "query {q}: depth-1 latency {lat} != service {}",
            t.service_ns
        );
    }
}

#[test]
fn coalesced_timeline_is_deterministic_across_worker_counts() {
    let mut cfg = cfg_queries(IndexKind::Ivf, 16);
    // IOPS headroom keeps rerank-ready instants close enough together
    // that the 50 us window reliably coalesces (the `max_batch >= 2`
    // check below needs real multi-member batches to be meaningful).
    cfg.sim.ssd_kiops = 4800.0;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let mut p1 = e1.profile_with(e1.params(), &dataset.queries);
    let mut p4 = e4.profile_with(e4.params(), &dataset.queries);
    for p in [&mut p1, &mut p4] {
        p.set_accel_rerank(AccelRerank::Batch);
        p.set_accel_batch_max(4);
        p.set_accel_batch_window_us(50.0);
    }
    let (a, ra) = p1.schedule(8, 0.0);
    let (b, rb) = p4.schedule(8, 0.0);
    // Warm scratches: a second run must not drift either.
    let (c, rc) = p4.schedule(8, 0.0);
    assert_eq!(a.len(), b.len());
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}: 1 vs 4 workers");
        assert_eq!(b[q].topk, c[q].topk, "query {q}: fresh vs warm scratch");
        assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "query {q}");
        assert_eq!(a[q].breakdown.accel_batch, b[q].breakdown.accel_batch, "query {q}");
        for (x, y) in [(&ra, &rb), (&rb, &rc)] {
            assert_eq!(x.timings[q].arrival_ns, y.timings[q].arrival_ns, "query {q}");
            assert_eq!(x.timings[q].admit_ns, y.timings[q].admit_ns, "query {q}");
            assert_eq!(x.timings[q].done_ns, y.timings[q].done_ns, "query {q}");
            assert_eq!(x.timings[q].service_ns, y.timings[q].service_ns, "query {q}");
        }
    }
    for (x, y) in [(&ra, &rb), (&rb, &rc)] {
        assert_eq!(x.makespan_ns, y.makespan_ns);
        assert_eq!(x.p99_ns, y.p99_ns);
        assert_eq!(x.accel.batches, y.accel.batches, "launch count");
        assert_eq!(x.accel.tasks, y.accel.tasks, "device task count");
        assert_eq!(x.accel.max_batch, y.accel.max_batch, "peak occupancy");
        assert_eq!(x.accel.xfer_queue_ns, y.accel.xfer_queue_ns, "link wait");
        assert_eq!(x.accel.accel_queue_ns, y.accel.accel_queue_ns, "device wait");
    }
    assert!(ra.accel.max_batch >= 2, "depth 8 must actually coalesce");
}

#[test]
fn coalescing_amortizes_the_launch_overhead_under_load() {
    let mut cfg = cfg_queries(IndexKind::Ivf, 24);
    // IOPS headroom so the fixed launch overhead — not the SSD fetch
    // path — is the batch-1 bottleneck; otherwise coalescing has nothing
    // to amortize against and the monotonicity below is vacuous.
    cfg.sim.ssd_kiops = 4800.0;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    profile.set_accel_rerank(AccelRerank::Batch);
    // A window well below the fixed launch overhead: waiting for a
    // batchmate can never cost more than the launch it saves.
    profile.set_accel_batch_window_us(20.0);
    let mut runs = Vec::new();
    for max in [1usize, 2, 4, 8] {
        profile.set_accel_batch_max(max);
        let (_, rep) = profile.schedule(16, 0.0);
        runs.push((max, rep));
    }
    let (_, single) = &runs[0];
    assert_eq!(single.accel.max_batch, 1, "batch_max = 1 must stay singleton");
    let tasks = single.accel.tasks;
    for (max, rep) in &runs[1..] {
        // Throughput is monotone in the admission cap: every coalescing
        // cap beats singleton launches — the amortized launch overhead
        // dwarfs any window wait at this depth.
        assert!(
            rep.makespan_ns < single.makespan_ns,
            "batch_max {max}: coalesced makespan {} not below singleton {}",
            rep.makespan_ns,
            single.makespan_ns
        );
        assert!(rep.accel.max_batch <= *max, "batch_max {max}: cap violated");
        assert!(rep.accel.max_batch >= 2, "batch_max {max}: never coalesced");
        assert!(
            rep.accel.batches < single.accel.batches,
            "batch_max {max}: coalescing must reduce launches"
        );
        assert!(rep.accel.mean_batch() > 1.0, "batch_max {max}: mean occupancy");
        assert_eq!(rep.accel.tasks, tasks, "batch_max {max}: device task count moved");
    }
    // Deeper caps never launch more often than shallower ones.
    for w in runs.windows(2) {
        assert!(
            w[1].1.accel.batches <= w[0].1.accel.batches,
            "batch_max {} launched more batches than {}",
            w[1].0,
            w[0].0
        );
    }
}

#[test]
fn zero_accel_fault_rate_is_structurally_inert() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut clean = engine.profile_with(engine.params(), &dataset.queries);
    let mut gated = engine.profile_with(engine.params(), &dataset.queries);
    for p in [&mut clean, &mut gated] {
        p.set_accel_rerank(AccelRerank::Batch);
        p.set_accel_batch_max(4);
        p.set_accel_batch_window_us(50.0);
    }
    // A plan with a nonzero seed but zero rates is disabled: the launch
    // fault branch must be structurally inert, not merely improbable.
    gated.set_fault(FaultConfig { seed: 0xACCE_17ED, ..Default::default() });
    for depth in [1usize, 8] {
        let (a, ra) = clean.schedule(depth, 0.0);
        let (b, rb) = gated.schedule(depth, 0.0);
        assert!(!rb.availability.active, "depth {depth}: zero plan flagged active");
        assert_eq!(ra.makespan_ns, rb.makespan_ns, "depth {depth}: makespan");
        assert_eq!(ra.accel.batches, rb.accel.batches, "depth {depth}: launches");
        assert_eq!(ra.accel.accel_queue_ns, rb.accel.accel_queue_ns, "depth {depth}");
        for q in 0..a.len() {
            assert_eq!(a[q].topk, b[q].topk, "depth {depth}: query {q}");
            assert_eq!(b[q].breakdown.retries, 0, "depth {depth}: query {q} retried");
            assert_eq!(
                ra.timings[q].done_ns, rb.timings[q].done_ns,
                "depth {depth}: query {q} done"
            );
        }
    }
}

#[test]
fn seeded_launch_faults_retry_whole_batches_deterministically() {
    let cfg = cfg_queries(IndexKind::Ivf, 16);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let mut p1 = e1.profile_with(e1.params(), &dataset.queries);
    let mut p4 = e4.profile_with(e4.params(), &dataset.queries);
    let plan = FaultConfig {
        seed: 41,
        accel_fail_rate: 0.5,
        retry_limit: 2,
        retry_backoff_us: 25.0,
        ..Default::default()
    };
    for p in [&mut p1, &mut p4] {
        p.set_accel_rerank(AccelRerank::Batch);
        p.set_accel_batch_max(4);
        p.set_accel_batch_window_us(50.0);
        p.set_fault(plan.clone());
    }
    let (a, ra) = p1.schedule(8, 0.0);
    let (b, rb) = p4.schedule(8, 0.0);
    let (_, rc) = p4.schedule(8, 0.0);
    assert!(ra.availability.active);
    assert!(
        ra.availability.retries > 0 || ra.availability.degraded > 0,
        "a 50% launch failure rate must trip the fault path"
    );
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}: 1 vs 4 workers");
        // One fault draw per launch attempt, shared by the whole batch:
        // a retried launch charges every member the same retry count.
        assert_eq!(a[q].breakdown.retries, b[q].breakdown.retries, "query {q}");
        for (x, y) in [(&ra, &rb), (&rb, &rc)] {
            assert_eq!(x.timings[q].done_ns, y.timings[q].done_ns, "query {q}");
            assert_eq!(x.timings[q].retries, y.timings[q].retries, "query {q}");
            assert_eq!(x.timings[q].degrade, y.timings[q].degrade, "query {q}");
        }
    }
    assert_eq!(ra.makespan_ns, rb.makespan_ns);
    assert_eq!(rb.makespan_ns, rc.makespan_ns);
    assert_eq!(ra.availability.retries, rb.availability.retries);
    assert_eq!(ra.availability.degraded, rb.availability.degraded);
    assert_eq!(ra.accel.batches, rb.accel.batches);
    assert_eq!(ra.accel.tasks, rb.accel.tasks);

    // Past the retry budget the whole batch degrades together: with a
    // certain failure and no retries, no launch ever succeeds, every
    // query falls back to its unverified ranking, and the device serves
    // nothing — while every query still returns k results.
    let mut doomed = e4.profile_with(e4.params(), &dataset.queries);
    doomed.set_accel_rerank(AccelRerank::Batch);
    doomed.set_accel_batch_max(4);
    doomed.set_accel_batch_window_us(50.0);
    doomed.set_fault(FaultConfig {
        seed: 41,
        accel_fail_rate: 1.0,
        retry_limit: 0,
        ..Default::default()
    });
    let (outs, rep) = doomed.schedule(8, 0.0);
    assert!(rep.availability.active);
    assert_eq!(rep.availability.degraded, outs.len(), "every query must degrade");
    assert_eq!(rep.availability.dropped, 0, "launch faults degrade, never drop");
    assert_eq!(rep.accel.tasks, 0, "no device task may survive a dead device");
    assert_eq!(rep.accel.batches, 0, "no launch may succeed at rate 1.0");
    for (q, out) in outs.iter().enumerate() {
        assert_eq!(out.topk.len(), a[q].topk.len(), "query {q}: degraded k");
    }
}
