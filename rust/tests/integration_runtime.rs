//! Integration: the XLA/PJRT runtime against native rust compute —
//! differential testing of all three AOT executables on real system data.
//!
//! Requires `make artifacts` (skips with a notice when absent, so plain
//! `cargo test` stays green in a fresh checkout).

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, SystemConfig,
};
use fatrq::coordinator::build_system;
use fatrq::refine::ProgressiveEstimator;
use fatrq::runtime::XlaRuntime;
use fatrq::util::l2_sq;
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    if dir.join("manifest.toml").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime integration tests: run `make artifacts` first");
        None
    }
}

/// A 768-D system matching the compiled artifact shapes.
fn sys_768() -> fatrq::coordinator::BuiltSystem {
    let cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 768,
            count: 2000,
            clusters: 16,
            noise: 0.35,
            query_noise: 1.0,
            queries: 4,
            seed: 99,
        },
        quant: QuantConfig { pq_m: 96, pq_nbits: 8, kmeans_iters: 3, train_sample: 1024 },
        index: IndexConfig { kind: IndexKind::Ivf, nlist: 16, nprobe: 8, ..Default::default() },
        refine: RefineConfig { candidates: 64, k: 10, calib_sample: 0.02, ..Default::default() },
        ..Default::default()
    };
    build_system(&cfg).unwrap()
}

#[test]
fn rerank_block_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(dir).unwrap();
    let sys = sys_768();
    let q = sys.dataset.query(0);
    // 100 vectors exercises the padding path (rerank_n = 64 -> 2 blocks).
    let n = 100usize;
    let mut vectors = vec![0f32; n * 768];
    for i in 0..n {
        vectors[i * 768..(i + 1) * 768].copy_from_slice(sys.dataset.vector(i));
    }
    let got = rt.rerank_block(q, &vectors).unwrap();
    assert_eq!(got.len(), n);
    for i in 0..n {
        let native = l2_sq(q, sys.dataset.vector(i));
        assert!(
            (got[i] - native).abs() < 1e-3 * native.max(1.0),
            "row {i}: xla {} native {native}",
            got[i]
        );
    }
}

#[test]
fn refine_block_matches_host_estimator() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(dir).unwrap();
    let sys = sys_768();
    let est = ProgressiveEstimator::new(&sys.trq, sys.cal.clone());
    let q = sys.dataset.query(1);
    let cands = sys.index.as_ann().search(q, 64);
    let d0: Vec<f32> = cands.iter().map(|c| c.dist).collect();
    let mut packed = Vec::new();
    let (mut scale, mut cross, mut dn) = (Vec::new(), Vec::new(), Vec::new());
    for c in &cands {
        let id = c.id as usize;
        packed.extend_from_slice(sys.trq.packed_row(id));
        scale.push(sys.trq.scale[id]);
        cross.push(sys.trq.cross[id]);
        dn.push(sys.trq.dnorm_sq[id]);
    }
    let got = rt
        .refine_block(q, &sys.cal.w, &d0, &packed, &scale, &cross, &dn)
        .unwrap();
    assert_eq!(got.len(), cands.len());
    for (j, c) in cands.iter().enumerate() {
        let native = est.estimate(q, c.id as usize, c.dist);
        assert!(
            (got[j] - native).abs() < 1e-2 + 1e-3 * native.abs(),
            "cand {j}: xla {} native {native}",
            got[j]
        );
    }
}

#[test]
fn coarse_scan_matches_native_adc() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(dir).unwrap();
    let sys = sys_768();
    let q = sys.dataset.query(2);
    let lut = sys.pq.adc_table(q);
    // Scan the first 500 codes (exercises tail padding, scan_n = 4096).
    let n = 500usize;
    let codes = &sys.codes[..n * sys.pq.m];
    let got = rt.coarse_scan(&lut, codes).unwrap();
    assert_eq!(got.len(), n);
    let mut native = vec![0f32; n];
    sys.pq.adc_scan(&lut, codes, &mut native);
    for i in 0..n {
        assert!(
            (got[i] - native[i]).abs() < 1e-2 + 1e-3 * native[i].abs(),
            "code {i}: xla {} native {}",
            got[i],
            native[i]
        );
    }
}

#[test]
fn manifest_validates_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::load(dir).unwrap();
    let m = rt.manifest;
    assert_eq!(m.dim, 768);
    assert_eq!(m.packed_bytes, 154);
    // Wrong-shape inputs must be rejected, not silently mis-executed.
    assert!(rt.rerank_block(&vec![0f32; 100], &vec![0f32; 768]).is_err());
    assert!(rt.coarse_scan(&vec![0f32; 7], &[0u8; 96]).is_err());
}
