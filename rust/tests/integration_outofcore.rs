//! Out-of-core paged corpus tier — end-to-end invariants.
//!
//! - **warm-cache bit-identity**: with the page tier attached but the
//!   cache warm (`cache.pages = 0`), the serving timeline, top-k,
//!   device accounting and makespan are bit-identical to the same
//!   build served fully in memory, across flat/IVF front stages ×
//!   every refine mode × pipeline depths. (One build serves both sides:
//!   PQ training
//!   is not bit-reproducible across builds — parallel k-means merges
//!   partial sums in completion order — and the contract is about
//!   serving, not training.)
//! - **cold-cache accounting**: a frame budget smaller than the working
//!   set pages in over the shard's shared SSD queue — misses and
//!   evictions show up in the cache columns, page-in *queue* time
//!   appears only when tasks overlap (depth > 1), the makespan grows,
//!   and the top-k never changes (paging is a timing concern only).
//! - **worker-count determinism**: cache counters, page-in queueing and
//!   the full timeline are identical across 1 vs 4 pool workers.
//! - **load monotonicity**: mean page-in queue time never decreases as
//!   the offered arrival rate grows, and the closed batch bounds every
//!   open-loop rate from above.
//! - **sharded serving**: per-shard caches and SSD queues keep the same
//!   warm/cold contracts over a scatter/gather engine.
//! - **per-tenant arrival traces**: a `trace=bursty` tenant replays
//!   exactly the generated trace while untraced tenants ride the
//!   global arrival process, deterministically across worker counts.
//! - **10M-vector scale** (`#[ignore]`d): the streaming build holds no
//!   reconstruction matrix and the cold tier serves from a cache
//!   budgeted at ≤ 25% of the paged bytes.

use fatrq::bench_support::gen_arrival_trace;
use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
    TenantSpec,
};
use fatrq::coordinator::{
    build_system_with, BuiltSystem, QueryEngine, QueryOutcome, QueryParams, ServeReport,
    ServeTiming, ShardedEngine,
};
use fatrq::vecstore::synthesize;
use std::sync::Arc;

fn base_cfg(kind: IndexKind) -> SystemConfig {
    let mut cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 32,
            count: 1600,
            clusters: 12,
            noise: 0.3,
            query_noise: 0.8,
            queries: 10,
            seed: 29,
        },
        quant: QuantConfig { pq_m: 8, pq_nbits: 5, kmeans_iters: 6, train_sample: 1200 },
        index: IndexConfig { kind, nlist: 16, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.02,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.sim.shared_timeline = true;
    cfg
}

fn oc_cfg(kind: IndexKind) -> SystemConfig {
    let mut cfg = base_cfg(kind);
    cfg.cache.out_of_core = true;
    cfg.cache.page_kb = 4;
    cfg.cache.pages = 0; // warm: everything resident
    cfg.cache.pin_pages = 2;
    cfg.validate().unwrap();
    cfg
}

/// One serving pass through the pipelined engine, returning ownership of
/// the system so tests can flip its page tier / cache budget between
/// runs (the whole point: compare configurations over ONE build).
fn serve_once(
    sys: BuiltSystem,
    mode: RefineMode,
    workers: usize,
    depth: usize,
    qps: f64,
) -> (Vec<QueryOutcome>, ServeReport, BuiltSystem) {
    let queries = sys.dataset.queries.clone();
    let params = QueryParams::from_config(&sys.cfg).with_mode(mode);
    let sys = Arc::new(sys);
    let (outs, rep) = {
        let engine = QueryEngine::with_threads(Arc::clone(&sys), workers);
        let profile = engine.profile_with(&params, &queries);
        profile.schedule(depth, qps)
    };
    let sys = Arc::try_unwrap(sys).ok().expect("engine dropped: sole owner");
    (outs, rep, sys)
}

fn assert_timings_bit_equal(a: &[ServeTiming], b: &[ServeTiming], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: timeline length");
    for (q, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.arrival_ns.to_bits(), y.arrival_ns.to_bits(), "{ctx}: q{q} arrival");
        assert_eq!(x.admit_ns.to_bits(), y.admit_ns.to_bits(), "{ctx}: q{q} admit");
        assert_eq!(x.done_ns.to_bits(), y.done_ns.to_bits(), "{ctx}: q{q} done");
        assert_eq!(x.service_ns.to_bits(), y.service_ns.to_bits(), "{ctx}: q{q} service");
    }
}

fn assert_outcomes_bit_equal(a: &[QueryOutcome], b: &[QueryOutcome], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: outcome count");
    for (q, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.topk, y.topk, "{ctx}: q{q} top-k diverged");
        assert_eq!(x.breakdown.far_reads, y.breakdown.far_reads, "{ctx}: q{q} far reads");
        assert_eq!(x.breakdown.ssd_reads, y.breakdown.ssd_reads, "{ctx}: q{q} ssd reads");
        assert_eq!(x.breakdown.far_ns.to_bits(), y.breakdown.far_ns.to_bits(), "{ctx}: q{q} far ns");
        assert_eq!(x.breakdown.ssd_ns.to_bits(), y.breakdown.ssd_ns.to_bits(), "{ctx}: q{q} ssd ns");
        assert_eq!(
            x.breakdown.queue_ns.to_bits(),
            y.breakdown.queue_ns.to_bits(),
            "{ctx}: q{q} queue ns"
        );
    }
}

#[test]
fn warm_cache_bit_identical_to_in_memory() {
    const MODES: [RefineMode; 3] = [RefineMode::Baseline, RefineMode::FatrqSw, RefineMode::FatrqHw];
    const DEPTHS: [usize; 3] = [1, 4, 16];
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = oc_cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let mut sys = build_system_with(&cfg, dataset).unwrap();
        let paged = sys.paged.take().expect("out-of-core build pages the cold tier");

        // In-memory reference: same build, page tier detached.
        let mut refs = Vec::new();
        for mode in MODES {
            for depth in DEPTHS {
                let (outs, rep, s) = serve_once(sys, mode, 2, depth, 0.0);
                sys = s;
                assert!(!rep.cache.active, "{}: no page tier, no cache columns", kind.name());
                assert_eq!(rep.cache.accesses, 0);
                refs.push((outs, rep));
            }
        }

        // Warm out-of-core: the replay runs, never misses, changes nothing
        // — for every refine mode at every depth.
        sys.paged = Some(paged);
        let mut refs = refs.iter();
        for mode in MODES {
            for depth in DEPTHS {
                let ctx = format!("{}/{mode:?}/depth {depth}", kind.name());
                let (outs, rep, s) = serve_once(sys, mode, 2, depth, 0.0);
                sys = s;
                let (ref_outs, ref_rep) = refs.next().unwrap();
                assert_outcomes_bit_equal(&outs, ref_outs, &ctx);
                assert_timings_bit_equal(&rep.timings, &ref_rep.timings, &ctx);
                assert_eq!(
                    rep.makespan_ns.to_bits(),
                    ref_rep.makespan_ns.to_bits(),
                    "{ctx}: makespan"
                );
                assert!(rep.cache.active, "{ctx}: warm cache still reports its columns");
                assert!(rep.cache.accesses > 0, "{ctx}: the page replay must run");
                assert_eq!(rep.cache.misses, 0, "{ctx}: warm cache can never miss");
                assert_eq!(rep.cache.evictions, 0, "{ctx}: warm cache never evicts");
                assert_eq!(rep.cache.hits, rep.cache.accesses);
                assert_eq!(rep.cache.hit_rate(), 1.0);
                assert_eq!(rep.mean_pagein_queue_ns, 0.0, "{ctx}: no misses, no page-in traffic");
            }
        }
    }
}

#[test]
fn cold_cache_misses_queue_on_the_shared_ssd() {
    let cfg = oc_cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let mut sys = build_system_with(&cfg, dataset).unwrap();
    let paged_pages = sys.paged.as_ref().unwrap().total_pages;
    let paged_pinned = sys.paged.as_ref().unwrap().pinned.len();

    // Warm reference over the same build.
    let (warm_outs, warm_rep, s) = serve_once(sys, RefineMode::FatrqHw, 2, 8, 0.0);
    sys = s;

    // Frame budget far below the per-query working set (nprobe covers
    // every list, one page per list).
    sys.cfg.cache.pages = 4;
    assert!(4 + paged_pinned < paged_pages, "budget must be cold for this test");

    // Depth 1: one task in flight ⇒ page-ins land on an idle SSD — cold
    // misses cost service time but never queue time.
    let (solo_outs, solo_rep, s) = serve_once(sys, RefineMode::FatrqHw, 2, 1, 0.0);
    sys = s;
    for (q, (c, w)) in solo_outs.iter().zip(&warm_outs).enumerate() {
        assert_eq!(c.topk, w.topk, "q{q}: paging must never change results");
    }
    assert!(solo_rep.cache.misses > 0, "cold cache must miss");
    assert_eq!(solo_rep.mean_pagein_queue_ns, 0.0, "depth 1: idle SSD, zero page-in queueing");
    assert!(
        solo_rep.makespan_ns > warm_rep.makespan_ns,
        "page-in service must stretch the cold makespan ({} vs warm {})",
        solo_rep.makespan_ns,
        warm_rep.makespan_ns
    );

    // Depth 8: overlapping tasks contend for the shard's SSD queue — the
    // misses now also show up as page-in queue time.
    let (cold_outs, cold_rep, _sys) = serve_once(sys, RefineMode::FatrqHw, 2, 8, 0.0);
    for (q, (c, w)) in cold_outs.iter().zip(&warm_outs).enumerate() {
        assert_eq!(c.topk, w.topk, "q{q}: paging must never change results");
    }
    let c = &cold_rep.cache;
    assert!(c.active);
    assert_eq!(c.frames, 4);
    assert_eq!(c.total_pages, paged_pages);
    assert_eq!(c.pinned, paged_pinned);
    assert!(c.misses > 0 && c.evictions > 0, "thrashing budget: {c:?}");
    assert!(c.hit_rate() < 1.0, "cold cache cannot be all hits: {c:?}");
    assert_eq!(c.hits + c.misses, c.accesses);
    assert!(
        cold_rep.mean_pagein_queue_ns > 0.0,
        "overlapping page-in bursts must queue on the shared SSD"
    );
    assert!(cold_rep.makespan_ns >= warm_rep.makespan_ns, "paging only adds time");
}

#[test]
fn paging_deterministic_across_worker_counts() {
    let cfg = oc_cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let mut sys = build_system_with(&cfg, dataset).unwrap();
    sys.cfg.cache.pages = 4; // cold: the interesting regime
    let sys = Arc::new(sys);
    let params = QueryParams::from_config(&sys.cfg);
    let run = |workers: usize| {
        let engine = QueryEngine::with_threads(Arc::clone(&sys), workers);
        let profile = engine.profile_with(&params, &sys.dataset.queries);
        profile.schedule(8, 15_000.0)
    };
    let (a_outs, a_rep) = run(1);
    let (b_outs, b_rep) = run(4);
    assert_outcomes_bit_equal(&a_outs, &b_outs, "1 vs 4 workers");
    assert_timings_bit_equal(&a_rep.timings, &b_rep.timings, "1 vs 4 workers");
    assert_eq!(a_rep.cache, b_rep.cache, "cache counters are part of the deterministic timeline");
    assert_eq!(a_rep.mean_pagein_queue_ns.to_bits(), b_rep.mean_pagein_queue_ns.to_bits());
    assert_eq!(a_rep.makespan_ns.to_bits(), b_rep.makespan_ns.to_bits());
}

#[test]
fn pagein_queue_time_monotone_in_offered_load() {
    // Lindley-style monotonicity observed end to end: admission order is
    // arrival order for a single tenant, so the miss pattern is
    // load-invariant — compressing the uniform arrival process only
    // increases overlap, and page-in queue time can only grow. The closed
    // batch (everything arrives at t = 0) is the densest arrival pattern
    // and upper-bounds every open-loop rate.
    let cfg = oc_cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let mut sys = build_system_with(&cfg, dataset).unwrap();
    sys.cfg.cache.pages = 4; // cold budget, fixed across the sweep
    let sys = Arc::new(sys);
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let profile = engine.profile_with(engine.params(), &sys.dataset.queries);

    // Saturation rate from the fully serialized schedule.
    let (_, solo) = profile.schedule(1, 0.0);
    assert!(solo.cache.misses > 0, "the budget must be cold for this sweep");
    let sat_qps = sys.dataset.num_queries() as f64 * 1e9 / solo.makespan_ns;

    let mut prev = 0.0f64;
    for load in [0.25, 1.0, 4.0] {
        let (_, rep) = profile.schedule(8, sat_qps * load);
        assert!(
            rep.mean_pagein_queue_ns >= prev,
            "page-in queue time must be monotone in offered load: {} at {load}x sat < {prev}",
            rep.mean_pagein_queue_ns
        );
        prev = rep.mean_pagein_queue_ns;
    }
    let (_, closed) = profile.schedule(8, 0.0);
    assert!(closed.mean_pagein_queue_ns >= prev, "closed batch is the densest arrival pattern");
    assert!(closed.mean_pagein_queue_ns > 0.0, "depth 8 over 4 frames must queue page-ins");
}

#[test]
fn sharded_out_of_core_warm_vs_cold() {
    let cfg = oc_cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    // One shard build, swept over cache budgets (shard builds are not
    // bit-reproducible, so the warm/cold comparison shares the build).
    let mut engine = ShardedEngine::from_dataset_with_threads(&cfg, &dataset, 2, 2).unwrap();
    engine.set_pipeline_depth(8);

    let (warm_outs, warm_rep) = engine.run_serve(engine.params(), &dataset.queries);
    assert!(warm_rep.cache.active, "per-shard page tiers must report cache columns");
    assert_eq!(warm_rep.cache.misses, 0, "pages=0 is warm on every shard");
    assert_eq!(warm_rep.mean_pagein_queue_ns, 0.0);

    engine.set_cache_pages(3);
    let (cold_outs, cold_rep) = engine.run_serve(engine.params(), &dataset.queries);
    for (q, (c, w)) in cold_outs.iter().zip(&warm_outs).enumerate() {
        assert_eq!(c.topk, w.topk, "q{q}: shard paging must never change merged results");
    }
    assert!(cold_rep.cache.misses > 0, "3 frames per shard must thrash");
    assert!(cold_rep.cache.hit_rate() < 1.0);
    assert!(
        cold_rep.mean_pagein_queue_ns > 0.0,
        "overlapping (query, shard) page-ins must queue per shard"
    );
    assert!(cold_rep.makespan_ns > warm_rep.makespan_ns, "cold shards pay page-in time");
}

#[test]
fn traced_tenant_replays_its_own_arrival_trace() {
    let mut cfg = base_cfg(IndexKind::Ivf);
    cfg.sim.arrival_qps = 20_000.0;
    cfg.serve.tenants = vec![
        TenantSpec { name: "burst".into(), weight: 1.0, quota: 0, trace: Some("bursty".into()) },
        TenantSpec { name: "steady".into(), weight: 1.0, quota: 0, trace: None },
    ];
    cfg.validate().unwrap();
    let dataset = synthesize(&cfg.dataset);
    let nq = dataset.num_queries();
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let tenant_of: Vec<usize> = (0..nq).map(|q| q % 2).collect();

    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let (_outs, rep) = engine.run_serve_tagged(engine.params(), &dataset.queries, &tenant_of);

    // Tenant 0 replays the generated bursty trace exactly (seeded off the
    // dataset seed + tenant index, at the global mean rate).
    let tr = gen_arrival_trace("bursty", nq, cfg.sim.arrival_qps, cfg.dataset.seed.wrapping_add(1))
        .unwrap();
    for (j, q) in (0..nq).step_by(2).enumerate() {
        assert_eq!(
            rep.timings[q].arrival_ns.to_bits(),
            tr[j].to_bits(),
            "traced tenant query {q} (its {j}-th) must arrive per its trace"
        );
    }
    // Tenant 1 rides the global uniform process untouched: evenly spaced
    // at the configured rate.
    let gap = 1e9 / cfg.sim.arrival_qps;
    for q in (1..nq).step_by(2) {
        assert_eq!(
            rep.timings[q].arrival_ns.to_bits(),
            (q as f64 * gap).to_bits(),
            "untraced tenant query {q} must keep its global arrival slot"
        );
    }

    // The mixture is deterministic across worker counts.
    let engine4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let (_outs4, rep4) = engine4.run_serve_tagged(engine4.params(), &dataset.queries, &tenant_of);
    assert_timings_bit_equal(&rep.timings, &rep4.timings, "traced tenants, 2 vs 4 workers");
}

/// 10M-vector out-of-core build + serve. Ignored by default: synthesis,
/// PQ/IVF training and the streamed TRQ build take minutes of wall clock
/// and ~1.5 GB of RAM. Run with `cargo test --release -- --ignored`.
#[test]
#[ignore = "10M-vector build: minutes of wall clock; run with --ignored"]
fn ten_million_vectors_serve_from_a_bounded_cache() {
    let mut cfg = oc_cfg(IndexKind::Ivf);
    cfg.dataset = DatasetConfig {
        dim: 16,
        count: 10_000_000,
        clusters: 64,
        noise: 0.3,
        query_noise: 0.8,
        queries: 4,
        seed: 41,
    };
    cfg.quant = QuantConfig { pq_m: 8, pq_nbits: 4, kmeans_iters: 3, train_sample: 50_000 };
    cfg.index = IndexConfig { kind: IndexKind::Ivf, nlist: 64, nprobe: 4, ..Default::default() };
    cfg.refine.candidates = 64;
    cfg.refine.calib_sample = 0.0001;
    cfg.cache.page_kb = 64;
    cfg.cache.pin_pages = 64;
    cfg.validate().unwrap();

    let dataset = synthesize(&cfg.dataset);
    let mut sys = build_system_with(&cfg, dataset).unwrap();
    assert!(sys.recon.is_empty(), "streaming build must not materialize the recon matrix");
    let (total_pages, cold_bytes) = {
        let paged = sys.paged.as_ref().unwrap();
        (paged.total_pages, paged.cold_bytes)
    };

    // Budget the cache at an eighth of the pages — resident footprint
    // (frames + pins) must stay under a quarter of the paged cold bytes.
    let frames = (total_pages / 8).max(1);
    sys.cfg.cache.pages = frames;
    let plan = sys.paged.as_ref().unwrap().plan(frames);
    assert!(!plan.warm(), "the 10M-scale cache must actually page");
    assert!(
        plan.resident_bytes() <= cold_bytes / 4,
        "resident {} must be ≤ 25% of cold {}",
        plan.resident_bytes(),
        cold_bytes
    );

    let (outs, rep, _sys) = serve_once(sys, RefineMode::FatrqHw, 4, 8, 0.0);
    assert_eq!(outs.len(), 4);
    for (q, o) in outs.iter().enumerate() {
        assert_eq!(o.topk.len(), cfg.refine.k, "q{q}: full top-k from the cold tier");
    }
    assert!(rep.cache.active && rep.cache.misses > 0, "cold start must page in");
    assert!(rep.makespan_ns > 0.0);
}
