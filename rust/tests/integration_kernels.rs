//! Integration: the SIMD dispatch layer — scalar and dispatched kernels
//! must be bit-identical on every input shape (the tiers mirror the same
//! eight-lane reduction), the ternary fallback threshold must never
//! change a result, and the whole `QueryEngine` must return identical
//! answers with the scalar tier pinned vs the detected tier. The CI
//! matrix additionally runs this entire suite under
//! `FATRQ_FORCE_SCALAR=1`, which pins the process-wide tier at first use;
//! in-process the same pin is exercised via `force_scalar_scope()`.

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, QueryEngine};
use fatrq::kernels::{
    adc_row, adc_row_scalar, adc_scan_topk, detected_tier, force_scalar_scope, l2_row,
    l2_row_scalar, l2_scan_topk, qdot_packed_tab, qdot_packed_tab_scalar, TernaryQueryLut,
    TERNARY_TAB_MIN_CANDIDATES,
};
use fatrq::quant::trq::qdot_packed;
use fatrq::quant::{pack_ternary, packed_len};
use fatrq::util::rng::Rng;
use fatrq::util::topk::TopK;
use std::sync::Arc;

/// Dims exercising every dispatch shape: below one 8-lane round, one
/// round + tail, exact multiples, the paper's 768, and 768 + ragged tail.
const DIMS: [usize; 5] = [5, 17, 64, 768, 769];

#[test]
fn l2_row_scalar_and_dispatched_are_bit_identical_unaligned() {
    let mut rng = Rng::new(101);
    for &dim in &DIMS {
        // Offsets 1 and 3 into a shared buffer force unaligned slices —
        // the kernels use unaligned loads and must not care.
        let buf_a: Vec<f32> = (0..dim + 4).map(|_| rng.gaussian_f32()).collect();
        let buf_b: Vec<f32> = (0..dim + 4).map(|_| rng.gaussian_f32()).collect();
        for (oa, ob) in [(0usize, 0usize), (1, 3), (3, 1)] {
            let a = &buf_a[oa..oa + dim];
            let b = &buf_b[ob..ob + dim];
            let s = l2_row_scalar(a, b);
            let d = l2_row(a, b);
            assert_eq!(d, s, "dim {dim} offsets ({oa},{ob}): tiers diverged");
            // Belt and braces on top of bit-identity: the documented
            // numeric budget.
            assert!((d - s).abs() <= 1e-5 * s.abs().max(1.0));
        }
    }
}

#[test]
fn adc_row_scalar_and_dispatched_are_bit_identical() {
    let mut rng = Rng::new(103);
    let ksub = 64usize;
    for &m in &DIMS {
        let lut: Vec<f32> = (0..m * ksub).map(|_| rng.gaussian_f32()).collect();
        for case in 0..4 {
            let code: Vec<u8> = (0..m).map(|_| (rng.next_u64() % ksub as u64) as u8).collect();
            let s = adc_row_scalar(&lut, ksub, &code);
            let d = adc_row(&lut, ksub, &code);
            assert_eq!(d, s, "m {m} case {case}: tiers diverged");
        }
    }
}

#[test]
fn ternary_fold_is_bit_identical_across_tiers_and_fallback() {
    let mut rng = Rng::new(107);
    for &dim in &DIMS {
        let mut tab = TernaryQueryLut::new();
        for case in 0..4 {
            let q: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
            let trits: Vec<i8> =
                (0..dim).map(|_| (rng.next_u64() % 3) as i8 - 1).collect();
            let mut packed = vec![0u8; packed_len(dim)];
            pack_ternary(&trits, &mut packed);
            tab.build(&q);
            let (dot_fb, k_fb) = qdot_packed(&q, &packed, dim);
            let (dot_s, k_s) = qdot_packed_tab_scalar(&tab, &packed);
            let (dot_d, k_d) = qdot_packed_tab(&tab, &packed);
            // Fallback ≡ scalar table ≡ dispatched table, bit for bit:
            // the threshold and the SIMD tier can never change a ranking.
            assert_eq!(dot_s, dot_fb, "dim {dim} case {case}: table vs fallback");
            assert_eq!(dot_d, dot_s, "dim {dim} case {case}: tiers diverged");
            assert_eq!((k_s, k_d), (k_fb, k_fb), "dim {dim} case {case}: live-trit count");
        }
    }
}

#[test]
fn scan_topk_results_identical_with_scalar_tier_pinned() {
    let mut rng = Rng::new(109);
    let (m, ksub) = (24usize, 64usize);
    // Candidate counts straddling the ternary table threshold double as
    // ragged / exact block sizes for the scans.
    for &n in &[
        TERNARY_TAB_MIN_CANDIDATES - 1,
        TERNARY_TAB_MIN_CANDIDATES,
        TERNARY_TAB_MIN_CANDIDATES + 1,
        200,
    ] {
        let lut: Vec<f32> = (0..m * ksub).map(|_| rng.gaussian_f32()).collect();
        let codes: Vec<u8> =
            (0..n * m).map(|_| (rng.next_u64() % ksub as u64) as u8).collect();
        let ids: Vec<u32> = (0..n as u32).collect();
        let dim = 96usize;
        let query: Vec<f32> = (0..dim).map(|_| rng.gaussian_f32()).collect();
        let data: Vec<f32> = (0..n * dim).map(|_| rng.gaussian_f32()).collect();

        let run = || {
            let mut dists = Vec::new();
            let mut top = TopK::new(10);
            adc_scan_topk(&lut, ksub, m, &codes, &ids, &mut dists, &mut top);
            let adc = top.take_sorted();
            let mut top = TopK::new(10);
            l2_scan_topk(&query, &data, dim, &mut dists, &mut top);
            (adc, top.take_sorted())
        };
        let dispatched = run();
        let scalar = {
            let _guard = force_scalar_scope();
            run()
        };
        for ((a, b), what) in [(&dispatched.0, &scalar.0), (&dispatched.1, &scalar.1)]
            .into_iter()
            .zip(["adc", "l2"])
        {
            assert_eq!(a.len(), b.len(), "n {n} {what}");
            for (x, y) in a.iter().zip(b.iter()) {
                assert_eq!(x.id, y.id, "n {n} {what}: ranking changed across tiers");
                assert_eq!(x.dist, y.dist, "n {n} {what}: distance changed across tiers");
            }
        }
    }
}

fn engine_cfg(candidates: usize) -> SystemConfig {
    SystemConfig {
        dataset: DatasetConfig {
            dim: 96,
            count: 3000,
            clusters: 24,
            noise: 0.35,
            query_noise: 1.0,
            queries: 12,
            seed: 91,
        },
        quant: QuantConfig { pq_m: 24, pq_nbits: 6, kmeans_iters: 5, train_sample: 2000 },
        index: IndexConfig {
            kind: IndexKind::Ivf,
            nlist: 48,
            nprobe: 12,
            graph_degree: 20,
            ef_search: 96,
            ef_construction: 96,
        },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates,
            k: 10,
            filter_ratio: 0.25,
            calib_sample: 0.01,
            early_exit: false,
            margin_quantile: 0.98,
        },
        ..Default::default()
    }
}

/// End-to-end contract from the dispatch layer: the full engine —
/// build, IVF probe, PQ scans, ternary refinement, early exit — returns
/// bit-identical answers with the scalar tier pinned and with the
/// detected tier, at candidate counts on both sides of the ternary-table
/// threshold (31 / 32 / 33).
#[test]
fn query_engine_identical_with_force_scalar_on_and_off() {
    let sys = Arc::new(build_system(&engine_cfg(120)).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    println!("detected tier: {}", detected_tier().name());
    for &candidates in &[
        TERNARY_TAB_MIN_CANDIDATES - 1,
        TERNARY_TAB_MIN_CANDIDATES,
        TERNARY_TAB_MIN_CANDIDATES + 1,
        120,
    ] {
        for &early_exit in &[false, true] {
            let mut params = engine.params().with_early_exit(early_exit);
            params.candidates = candidates;
            let dispatched = engine.run_with(&params, &sys.dataset.queries);
            let scalar = {
                let _guard = force_scalar_scope();
                engine.run_with(&params, &sys.dataset.queries)
            };
            assert_eq!(dispatched.len(), scalar.len());
            for (q, (a, b)) in dispatched.iter().zip(&scalar).enumerate() {
                assert_eq!(
                    a.topk.len(),
                    b.topk.len(),
                    "query {q} cands {candidates} ee {early_exit}"
                );
                for (x, y) in a.topk.iter().zip(&b.topk) {
                    assert_eq!(
                        x.id, y.id,
                        "query {q} cands {candidates} ee {early_exit}: ids diverged"
                    );
                    assert_eq!(
                        x.dist, y.dist,
                        "query {q} cands {candidates} ee {early_exit}: dists diverged"
                    );
                    // Documented fallback budget, trivially satisfied by
                    // bit-identity.
                    assert!((x.dist - y.dist).abs() <= 1e-5 * y.dist.abs().max(1.0));
                }
            }
        }
    }
}
