//! Seeded fault injection + degraded-mode serving — end-to-end contracts.
//!
//! - **zero-fault bit-identity**: a configured-but-disabled fault plan
//!   (all rates zero, no outages) and a zero deadline leave the serving
//!   timeline, top-k, queue_ns and I/O accounting bit-identical to a run
//!   that never heard of faults — across flat/IVF front stages × all
//!   refine modes × pipeline depths {1, 4, 16}.
//! - **worker-count determinism under faults**: a nonzero seeded plan
//!   produces the same timeline, retry counts and degrade levels across
//!   1 vs 4 pool workers and repeated runs (the plan is a pure function
//!   of (seed, device, op), never of host scheduling).
//! - **graceful degradation**: every non-dropped query still returns k
//!   results, with its `DegradeLevel` reported; latency spikes delay but
//!   never change results; deadlines convert waiting into coarse
//!   fallbacks.
//! - **shard outages**: queries keep serving partial results from the
//!   surviving shards, and the partial recall stays within the bound
//!   implied by the dropped shard's share of the ground truth.

use fatrq::config::{
    DatasetConfig, FaultConfig, IndexConfig, IndexKind, OutageSpec, QuantConfig, RefineConfig,
    RefineMode, SystemConfig,
};
use fatrq::coordinator::{
    build_system_with, ground_truth_for, QueryEngine, QueryParams, ShardedEngine,
};
use fatrq::metrics::recall_at_k;
use fatrq::simulator::DegradeLevel;
use fatrq::vecstore::synthesize;
use std::sync::Arc;

fn cfg(kind: IndexKind) -> SystemConfig {
    let mut cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 32,
            count: 1600,
            clusters: 12,
            noise: 0.3,
            query_noise: 0.8,
            queries: 10,
            seed: 23,
        },
        quant: QuantConfig { pq_m: 8, pq_nbits: 5, kmeans_iters: 6, train_sample: 1200 },
        index: IndexConfig { kind, nlist: 16, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.02,
            ..Default::default()
        },
        ..Default::default()
    };
    cfg.sim.shared_timeline = true;
    cfg
}

/// A plan with every failure channel hot (rates high enough that a
/// 10-query batch reliably hits each).
fn hot_plan(seed: u64) -> FaultConfig {
    FaultConfig {
        seed,
        far_fail_rate: 0.4,
        far_spike_rate: 0.3,
        far_spike_us: 40.0,
        ssd_fail_rate: 0.4,
        retry_limit: 2,
        retry_backoff_us: 25.0,
        ..Default::default()
    }
}

#[test]
fn zero_fault_plan_is_bit_identical_to_fault_free() {
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
        let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
        for (mode, early_exit) in [
            (RefineMode::Baseline, false),
            (RefineMode::FatrqSw, false),
            (RefineMode::FatrqHw, false),
            (RefineMode::FatrqHw, true),
        ] {
            let params =
                QueryParams::from_config(&cfg).with_mode(mode).with_early_exit(early_exit);
            let baseline = engine.profile_with(&params, &dataset.queries);
            let mut gated = engine.profile_with(&params, &dataset.queries);
            // A plan with a nonzero seed but zero rates is disabled: the
            // fault branches must be structurally inert.
            gated.set_fault(FaultConfig { seed: 0xDEAD_BEEF, ..Default::default() });
            gated.set_deadline_us(0.0);
            for depth in [1usize, 4, 16] {
                let (a, ra) = baseline.schedule(depth, 0.0);
                let (b, rb) = gated.schedule(depth, 0.0);
                let tag = format!("{}/{mode:?}/ee={early_exit}/depth={depth}", kind.name());
                assert_eq!(ra.makespan_ns, rb.makespan_ns, "{tag}: makespan");
                assert_eq!(ra.p99_ns, rb.p99_ns, "{tag}: p99");
                assert!(!rb.availability.active, "{tag}: zero plan flagged active");
                for q in 0..a.len() {
                    assert_eq!(a[q].topk, b[q].topk, "{tag}: query {q} top-k");
                    assert_eq!(
                        a[q].breakdown.queue_ns, b[q].breakdown.queue_ns,
                        "{tag}: query {q} queue"
                    );
                    assert_eq!(a[q].breakdown.far_ns, b[q].breakdown.far_ns, "{tag}: {q}");
                    assert_eq!(a[q].breakdown.ssd_reads, b[q].breakdown.ssd_reads, "{tag}: {q}");
                    assert_eq!(b[q].breakdown.retries, 0, "{tag}: query {q} retried");
                    assert_eq!(
                        ra.timings[q].done_ns, rb.timings[q].done_ns,
                        "{tag}: query {q} done"
                    );
                    assert_eq!(
                        ra.timings[q].admit_ns, rb.timings[q].admit_ns,
                        "{tag}: query {q} admit"
                    );
                    assert_eq!(rb.timings[q].degrade, DegradeLevel::Full, "{tag}: {q}");
                    assert_eq!(rb.timings[q].retries, 0, "{tag}: query {q}");
                    assert!(!rb.timings[q].deadline_missed, "{tag}: query {q}");
                }
            }
        }
    }
}

#[test]
fn seeded_faults_are_deterministic_across_worker_counts() {
    let mut cfg = cfg(IndexKind::Ivf);
    cfg.sim.fault = hot_plan(11);
    cfg.serve.pipeline_depth = 4;
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
    let e4 = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let (a, ra) = e1.run_serve(e1.params(), &dataset.queries);
    let (b, rb) = e4.run_serve(e4.params(), &dataset.queries);
    let (_, rc) = e4.run_serve(e4.params(), &dataset.queries);
    assert!(ra.availability.active);
    assert!(
        ra.availability.retries > 0 || ra.availability.degraded > 0,
        "a hot plan over 10 queries should fire at least once"
    );
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}: 1 vs 4 workers under faults");
        assert_eq!(a[q].breakdown.retries, b[q].breakdown.retries, "query {q}");
        assert_eq!(a[q].breakdown.degrade, b[q].breakdown.degrade, "query {q}");
        for (x, y) in [(&ra, &rb), (&rb, &rc)] {
            assert_eq!(x.timings[q].done_ns, y.timings[q].done_ns, "query {q}");
            assert_eq!(x.timings[q].admit_ns, y.timings[q].admit_ns, "query {q}");
            assert_eq!(x.timings[q].degrade, y.timings[q].degrade, "query {q}");
            assert_eq!(x.timings[q].retries, y.timings[q].retries, "query {q}");
        }
    }
    assert_eq!(ra.makespan_ns, rb.makespan_ns);
    assert_eq!(ra.availability.retries, rb.availability.retries);
    assert_eq!(ra.availability.degraded, rb.availability.degraded);
    // Every non-dropped query still returns its full k.
    let k = cfg.refine.k;
    for (q, out) in a.iter().enumerate() {
        if ra.timings[q].degrade != DegradeLevel::Dropped {
            assert_eq!(out.topk.len(), k, "query {q} lost results while degrading");
        }
    }
}

#[test]
fn latency_spikes_delay_but_never_change_results() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let baseline = engine.profile_with(engine.params(), &dataset.queries);
    let mut spiky = engine.profile_with(engine.params(), &dataset.queries);
    spiky.set_fault(FaultConfig {
        seed: 5,
        far_spike_rate: 0.8,
        far_spike_us: 100.0,
        ..Default::default()
    });
    let (a, ra) = baseline.schedule(4, 0.0);
    let (b, rb) = spiky.schedule(4, 0.0);
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}: spikes must not change results");
        assert_eq!(rb.timings[q].degrade, DegradeLevel::Full, "query {q}");
    }
    // Spikes only add simulated time. (Per-query completions may reorder
    // — a delayed stream frees the device for a neighbor — but the 100 us
    // spikes dwarf any such queueing savings in aggregate.)
    assert!(
        rb.makespan_ns > ra.makespan_ns,
        "an 80% spike rate must stretch the makespan: {} !> {}",
        rb.makespan_ns,
        ra.makespan_ns
    );
    assert!(rb.mean_latency_ns > ra.mean_latency_ns);
    assert!(rb.availability.active);
    assert_eq!(rb.availability.served, a.len());
    assert_eq!(rb.availability.degraded, 0);
}

#[test]
fn deadlines_degrade_to_coarse_but_keep_k_results() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    let (_, full) = profile.schedule(0, 0.0);
    // 1 ns deadline over a closed batch: everything past the first far
    // admission is late, so queries fall back to the coarse ranking
    // instead of waiting out the pipeline.
    profile.set_deadline_us(1e-3);
    let (outs, rep) = profile.schedule(0, 0.0);
    let k = cfg.refine.k;
    assert!(rep.availability.active);
    assert!(
        rep.availability.degraded > 0,
        "a 1 ns deadline must degrade at least one query"
    );
    assert_eq!(rep.availability.dropped, 0, "deadlines degrade, never drop");
    assert!(rep.availability.deadline_missed > 0);
    for (q, out) in outs.iter().enumerate() {
        assert_eq!(out.topk.len(), k, "query {q} lost results while degrading");
        assert!(
            rep.timings[q].degrade <= DegradeLevel::CoarseOnly,
            "query {q}: deadline produced {}",
            rep.timings[q].degrade.name()
        );
        assert_eq!(out.breakdown.degrade, rep.timings[q].degrade, "query {q}");
    }
    // The degraded schedule finishes no later than the full pipeline:
    // skipped stages only remove simulated work.
    assert!(rep.makespan_ns <= full.makespan_ns * (1.0 + 1e-9));
}

#[test]
fn monolithic_outage_drops_queries_and_reports_them() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let mut profile = engine.profile_with(engine.params(), &dataset.queries);
    // The monolithic engine is one "shard": a whole-run outage window on
    // it drops every query that reaches far memory inside the window.
    profile.set_fault(FaultConfig {
        seed: 3,
        outages: vec![OutageSpec { shard: 0, start_us: 0.0, end_us: 1e12 }],
        ..Default::default()
    });
    let (outs, rep) = profile.schedule(1, 0.0);
    assert!(rep.availability.active);
    assert_eq!(
        rep.availability.dropped,
        outs.len(),
        "a whole-run outage must drop every query"
    );
    assert_eq!(rep.availability.served, 0);
    for (q, out) in outs.iter().enumerate() {
        assert_eq!(rep.timings[q].degrade, DegradeLevel::Dropped, "query {q}");
        assert!(out.topk.is_empty(), "query {q}: dropped query returned results");
    }
}

#[test]
fn shard_outage_serves_partial_results_within_the_recall_bound() {
    let mut cfg = cfg(IndexKind::Ivf);
    // Deep candidates relative to each shard keep the merge unambiguous
    // (the sharded bit-identity test's setting).
    cfg.refine.candidates = 300;
    cfg.refine.filter_ratio = 1.0;
    let dataset = synthesize(&cfg.dataset);
    let k = cfg.refine.k;
    let truth = ground_truth_for(&dataset, k);
    let shards = 4usize;
    let mut engine = ShardedEngine::from_dataset_with_threads(&cfg, &dataset, shards, 2).unwrap();
    engine.set_pipeline_depth(4);
    let full = engine.run(&dataset.queries);

    // Take shard 1 out for the whole run: its tasks drop, every query is
    // served partial from the three survivors.
    let down = 1usize;
    engine.set_fault(FaultConfig {
        seed: 9,
        outages: vec![OutageSpec { shard: down, start_us: 0.0, end_us: 1e12 }],
        ..Default::default()
    });
    let params = *engine.params();
    let (partial, rep) = engine.run_serve(&params, &dataset.queries);

    // Shards hold contiguous id ranges in order; recover shard `down`'s
    // global range from the per-shard counts.
    let mut lo = 0usize;
    for s in 0..down {
        lo += engine.shard(s).dataset.count();
    }
    let hi = lo + engine.shard(down).dataset.count();

    assert!(rep.availability.active);
    assert_eq!(rep.availability.dropped, 0, "survivors must keep every query alive");
    assert_eq!(rep.availability.served, partial.len());
    assert_eq!(rep.availability.dropped_tasks, partial.len(), "one dropped task per query");
    for (q, out) in partial.iter().enumerate() {
        assert_eq!(rep.timings[q].degrade, DegradeLevel::Partial, "query {q}");
        assert_eq!(out.topk.len(), k, "query {q}: partial result must still fill k");
        // Nothing from the dead shard can appear...
        for c in &out.topk {
            assert!(
                (c.id as usize) < lo || (c.id as usize) >= hi,
                "query {q}: result id {} came from the down shard",
                c.id
            );
        }
        // ...and the recall loss is bounded by the dead shard's share of
        // the ground truth: every surviving true neighbor stays findable.
        let lost =
            truth[q].iter().take(k).filter(|c| (c.id as usize) >= lo && (c.id as usize) < hi).count();
        let bound = recall_at_k(&full[q].topk, &truth[q], k) - lost as f64 / k as f64;
        let got = recall_at_k(&out.topk, &truth[q], k);
        assert!(
            got + 1e-9 >= bound,
            "query {q}: partial recall {got} below the surviving-shard bound {bound}"
        );
    }
}

#[test]
fn retries_recover_reads_without_changing_results() {
    let cfg = cfg(IndexKind::Ivf);
    let dataset = synthesize(&cfg.dataset);
    let sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    let baseline = engine.profile_with(engine.params(), &dataset.queries);
    let (a, _) = baseline.schedule(4, 0.0);
    let mut flaky = engine.profile_with(engine.params(), &dataset.queries);
    // Failures at a rate the retry budget mostly absorbs: with p = 0.3
    // and 4 attempts, exhausting a budget takes four consecutive fails
    // (p^4 < 1%) — most queries recover with retries > 0.
    flaky.set_fault(FaultConfig {
        seed: 21,
        far_fail_rate: 0.3,
        ssd_fail_rate: 0.3,
        retry_limit: 3,
        retry_backoff_us: 10.0,
        ..Default::default()
    });
    let (b, rb) = flaky.schedule(4, 0.0);
    assert!(rb.availability.retries > 0, "a 30% failure rate must retry");
    for q in 0..a.len() {
        if rb.timings[q].degrade == DegradeLevel::Full {
            assert_eq!(
                a[q].topk, b[q].topk,
                "query {q}: recovered retries must not change results"
            );
            if rb.timings[q].retries > 0 {
                assert!(
                    rb.timings[q].done_ns > 0.0 && b[q].breakdown.retries > 0,
                    "query {q}: retry count must surface in the breakdown"
                );
            }
        }
    }
}
