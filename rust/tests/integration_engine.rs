//! Integration: the persistent QueryEngine — early-exit correctness
//! against the classic filter_top_ratio path, and determinism of the
//! scratch-reusing batch path across thread counts.

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, QueryEngine};
use fatrq::index::FlatIndex;
use fatrq::metrics::recall_at_k;
use std::sync::Arc;

fn cfg() -> SystemConfig {
    SystemConfig {
        dataset: DatasetConfig {
            dim: 96,
            count: 6000,
            clusters: 48,
            noise: 0.35,
            query_noise: 1.0,
            queries: 32,
            seed: 77,
        },
        quant: QuantConfig { pq_m: 24, pq_nbits: 6, kmeans_iters: 6, train_sample: 4000 },
        index: IndexConfig {
            kind: IndexKind::Ivf,
            nlist: 64,
            nprobe: 16,
            graph_degree: 20,
            ef_search: 96,
            ef_construction: 96,
        },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 120,
            k: 10,
            filter_ratio: 0.25,
            calib_sample: 0.01,
            early_exit: false,
            margin_quantile: 0.98,
        },
        ..Default::default()
    }
}

/// The paper's early-exit claim, end to end: enabling `early_exit` keeps
/// recall@10 within 1% of the static filter_top_ratio policy while issuing
/// strictly fewer far-memory reads (and strictly fewer than `candidates`).
#[test]
fn early_exit_matches_ratio_recall_with_fewer_far_reads() {
    let sys = Arc::new(build_system(&cfg()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 4);
    let classic = engine.params().with_early_exit(false);
    let progressive = engine.params().with_early_exit(true);

    let outs_classic = engine.run_with(&classic, &sys.dataset.queries);
    let outs_ee = engine.run_with(&progressive, &sys.dataset.queries);

    let flat = FlatIndex::new(sys.dataset.base.clone(), sys.dataset.dim);
    let nq = sys.dataset.num_queries();
    let (mut r_classic, mut r_ee) = (0.0f64, 0.0f64);
    let (mut far_classic, mut far_ee, mut cands) = (0usize, 0usize, 0usize);
    for q in 0..nq {
        let truth = flat.search_exact(sys.dataset.query(q), 10);
        r_classic += recall_at_k(&outs_classic[q].topk, &truth, 10);
        r_ee += recall_at_k(&outs_ee[q].topk, &truth, 10);
        far_classic += outs_classic[q].breakdown.far_reads;
        far_ee += outs_ee[q].breakdown.far_reads;
        cands += outs_ee[q].breakdown.candidates;
        assert_eq!(outs_ee[q].topk.len(), 10);
    }
    r_classic /= nq as f64;
    r_ee /= nq as f64;

    // Classic streams every candidate; the progressive walk must not.
    assert_eq!(far_classic, cands);
    assert!(far_ee < far_classic, "far reads: ee {far_ee} !< classic {far_classic}");
    assert!(far_ee < cands, "far reads {far_ee} !< candidates {cands}");
    assert!(
        r_ee >= r_classic - 0.01,
        "early-exit recall {r_ee:.4} fell more than 1% below ratio-filter {r_classic:.4}"
    );
}

/// Determinism with reused scratch: a 1-worker engine and an N-worker
/// engine must produce identical top-k lists and identical IO accounting,
/// in both refinement flavours, and repeated runs on warm scratch must not
/// drift.
#[test]
fn engine_deterministic_one_vs_many_threads() {
    for early_exit in [false, true] {
        let mut c = cfg();
        c.refine.early_exit = early_exit;
        let sys = Arc::new(build_system(&c).unwrap());
        let e1 = QueryEngine::with_threads(Arc::clone(&sys), 1);
        let e8 = QueryEngine::with_threads(Arc::clone(&sys), 8);
        let a = e1.run(&sys.dataset.queries);
        let b = e8.run(&sys.dataset.queries);
        let warm = e8.run(&sys.dataset.queries);
        assert_eq!(a.len(), sys.dataset.num_queries());
        for q in 0..a.len() {
            assert_eq!(a[q].topk, b[q].topk, "early_exit={early_exit} query {q}");
            assert_eq!(b[q].topk, warm[q].topk, "warm scratch drifted, query {q}");
            assert_eq!(
                a[q].breakdown.far_reads, b[q].breakdown.far_reads,
                "early_exit={early_exit} query {q} far reads"
            );
            assert_eq!(a[q].breakdown.ssd_reads, b[q].breakdown.ssd_reads);
        }
    }
}

/// The engine honours per-call mode overrides without rebuilding, and all
/// three modes return valid sorted top-k lists.
#[test]
fn engine_mode_overrides() {
    let sys = Arc::new(build_system(&cfg()).unwrap());
    let engine = QueryEngine::with_threads(Arc::clone(&sys), 2);
    for mode in [RefineMode::Baseline, RefineMode::FatrqSw, RefineMode::FatrqHw] {
        let outs = engine.run_with(&engine.params().with_mode(mode), &sys.dataset.queries);
        for out in &outs {
            assert_eq!(out.topk.len(), 10, "{mode:?}");
            for w in out.topk.windows(2) {
                assert!(w[0].dist <= w[1].dist);
            }
        }
    }
}
