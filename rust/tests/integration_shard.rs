//! Sharded scatter/gather serving — end-to-end invariants.
//!
//! - **shard-vs-monolith equivalence**: on the same seeded dataset, a
//!   `ShardedEngine` with N ∈ {1, 2, 4} shards returns bit-identical
//!   top-k ids and distances to the monolithic `QueryEngine`, for flat and
//!   IVF front stages and all three refine modes. The equivalence config
//!   keeps every candidate through refinement (`filter_ratio = 1.0`) so
//!   the test isolates what sharding must preserve: front-stage coverage,
//!   global-id remapping, exact rerank, and the `(distance, id)` merge
//!   tie rule.
//! - **determinism**: identical results across 1 vs 4 pool workers and
//!   across repeated runs with reused scratch, shared timeline included.
//! - **early-exit × sharding**: per-shard progressive walks keep the
//!   aggregate `far_reads < candidates` and recall within 1% of the
//!   unsharded early-exit path at N = 4.

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{
    build_system_with, ground_truth_for, QueryEngine, QueryParams, ShardedEngine,
};
use fatrq::metrics::recall_at_k;
use fatrq::vecstore::synthesize;
use std::sync::Arc;

/// Equivalence config: all lists probed, nothing filtered (see module
/// docs), queries close to their seed vectors so the exact top-k is
/// unambiguous.
fn equiv_cfg(kind: IndexKind) -> SystemConfig {
    SystemConfig {
        dataset: DatasetConfig {
            dim: 32,
            count: 1600,
            clusters: 12,
            noise: 0.3,
            query_noise: 0.8,
            queries: 10,
            seed: 23,
        },
        quant: QuantConfig { pq_m: 8, pq_nbits: 5, kmeans_iters: 6, train_sample: 1200 },
        index: IndexConfig { kind, nlist: 16, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            // Deep relative to the corpus (300 of 1600 monolithic, 300 of
            // ~400 per shard at N = 4): every true top-10 member lands in
            // each arrangement's candidate pool with enormous margin, so
            // the exact rerank pins the same global top-k everywhere.
            candidates: 300,
            k: 10,
            filter_ratio: 1.0,
            calib_sample: 0.02,
            ..Default::default()
        },
        ..Default::default()
    }
}

#[test]
fn sharded_topk_matches_monolith_for_flat_and_ivf_all_modes() {
    for kind in [IndexKind::Flat, IndexKind::Ivf] {
        let cfg = equiv_cfg(kind);
        let dataset = synthesize(&cfg.dataset);
        let mono_sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
        let mono = QueryEngine::with_threads(Arc::clone(&mono_sys), 2);
        for shards in [1usize, 2, 4] {
            let sharded =
                ShardedEngine::from_dataset_with_threads(&cfg, &dataset, shards, 2).unwrap();
            for mode in [RefineMode::Baseline, RefineMode::FatrqSw, RefineMode::FatrqHw] {
                let params = QueryParams::from_config(&cfg).with_mode(mode);
                let want = mono.run_with(&params, &dataset.queries);
                let got = sharded.run_with(&params, &dataset.queries);
                assert_eq!(want.len(), got.len());
                for (q, (w, g)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        w.topk, g.topk,
                        "{}/{mode:?}: query {q} diverged at {shards} shards",
                        kind.name()
                    );
                }
            }
        }
    }
}

#[test]
fn sharded_run_is_deterministic_across_workers_and_reuse() {
    let mut cfg = equiv_cfg(IndexKind::Ivf);
    cfg.refine.early_exit = true;
    cfg.sim.shared_timeline = true;
    let dataset = synthesize(&cfg.dataset);
    // One shard build, re-pooled at different worker counts (shard builds
    // are not bit-reproducible — parallel k-means merges partial sums in
    // completion order — so the comparison must share the build).
    let engine = ShardedEngine::from_dataset_with_threads(&cfg, &dataset, 4, 1).unwrap();
    let a = engine.run(&dataset.queries);
    let engine = engine.with_worker_threads(4);
    let b = engine.run(&dataset.queries);
    // Run again so the per-worker scratches carry history.
    let c = engine.run(&dataset.queries);
    assert_eq!(a.len(), b.len());
    for q in 0..a.len() {
        assert_eq!(a[q].topk, b[q].topk, "query {q}: 1 vs 4 workers");
        assert_eq!(b[q].topk, c[q].topk, "query {q}: fresh vs reused scratch");
        assert_eq!(a[q].breakdown.far_reads, b[q].breakdown.far_reads, "query {q}");
        assert_eq!(a[q].breakdown.queue_ns, b[q].breakdown.queue_ns, "query {q}");
        assert_eq!(b[q].breakdown.queue_ns, c[q].breakdown.queue_ns, "query {q}");
    }
}

#[test]
fn sharded_early_exit_keeps_recall_and_cuts_far_reads() {
    // The progressive-walk config: candidates are genuinely filtered, so
    // early exit has something to save (extends the engine's
    // `early_exit_reduces_far_reads_and_keeps_recall` pattern to 4
    // shards).
    let cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 64,
            count: 4000,
            clusters: 32,
            noise: 0.35,
            query_noise: 1.0,
            queries: 16,
            seed: 5,
        },
        quant: QuantConfig { pq_m: 16, pq_nbits: 6, kmeans_iters: 6, train_sample: 2048 },
        index: IndexConfig { kind: IndexKind::Ivf, nlist: 32, nprobe: 10, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 100,
            k: 10,
            filter_ratio: 0.3,
            calib_sample: 0.01,
            early_exit: true,
            margin_quantile: 0.98,
        },
        ..Default::default()
    };
    let dataset = synthesize(&cfg.dataset);
    let truth = ground_truth_for(&dataset, 10);

    let mono_sys = Arc::new(build_system_with(&cfg, dataset.clone()).unwrap());
    let mono = QueryEngine::with_threads(Arc::clone(&mono_sys), 2);
    let sharded = ShardedEngine::from_dataset_with_threads(&cfg, &dataset, 4, 2).unwrap();

    let outs_mono = mono.run(&dataset.queries);
    let outs_shard = sharded.run(&dataset.queries);

    let nq = dataset.num_queries();
    let (mut r_mono, mut r_shard) = (0.0f64, 0.0f64);
    let (mut far, mut cands) = (0usize, 0usize);
    for q in 0..nq {
        r_mono += recall_at_k(&outs_mono[q].topk, &truth[q], 10);
        r_shard += recall_at_k(&outs_shard[q].topk, &truth[q], 10);
        // Aggregate (summed-across-shards) counts: the per-shard
        // progressive walks must still stream less than the combined
        // candidate pool.
        far += outs_shard[q].breakdown.far_reads;
        cands += outs_shard[q].breakdown.candidates;
    }
    r_mono /= nq as f64;
    r_shard /= nq as f64;
    assert!(
        far < cands,
        "sharded early exit: aggregate far reads {far} !< candidates {cands}"
    );
    assert!(
        r_shard >= r_mono - 0.01,
        "sharded early-exit recall {r_shard:.4} fell more than 1% below unsharded {r_mono:.4}"
    );
}
