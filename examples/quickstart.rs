//! Quickstart: build a small FaTRQ system, serve a few queries, print
//! recall and the per-stage breakdown.
//!
//! Run with: `cargo run --release --example quickstart`

use fatrq::config::{DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig};
use fatrq::coordinator::{build_system, Pipeline};
use fatrq::index::FlatIndex;
use fatrq::metrics::recall_at_k;

fn main() -> anyhow::Result<()> {
    // A laptop-scale corpus: 20k x 128-D clustered embeddings.
    let cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 128,
            count: 20_000,
            clusters: 128,
            noise: 0.35,
            query_noise: 1.0,
            queries: 64,
            seed: 42,
        },
        quant: QuantConfig { pq_m: 32, pq_nbits: 8, kmeans_iters: 8, train_sample: 8192 },
        index: IndexConfig {
            kind: IndexKind::Ivf,
            nlist: 128,
            nprobe: 16,
            ..Default::default()
        },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 100,
            k: 10,
            filter_ratio: 0.25,
            calib_sample: 0.01,
            ..Default::default()
        },
        ..Default::default()
    };

    println!("building system ({} x {}D)...", cfg.dataset.count, cfg.dataset.dim);
    let t0 = std::time::Instant::now();
    let sys = build_system(&cfg)?;
    println!("built in {:.1}s", t0.elapsed().as_secs_f64());
    println!(
        "  far-memory record: {} B ({} packed + 8 scalar)",
        sys.trq.record_bytes(),
        sys.trq.record_bytes() - 8
    );

    // Exact ground truth for recall measurement.
    let flat = FlatIndex::new(sys.dataset.base.clone(), sys.dataset.dim);

    let pipeline = Pipeline::new(&sys);
    let mut recall = 0.0;
    let nq = sys.dataset.num_queries();
    for q in 0..nq {
        let query = sys.dataset.query(q);
        let out = pipeline.query(query);
        let truth = flat.search_exact(query, 10);
        recall += recall_at_k(&out.topk, &truth, 10);
        if q == 0 {
            let bd = out.breakdown;
            println!("\nfirst query breakdown:");
            println!("  traversal : {:>9.1} us", bd.traversal_ns / 1e3);
            println!("  far memory: {:>9.1} us ({} reads)", bd.far_ns / 1e3, bd.far_reads);
            println!("  refine    : {:>9.1} us", bd.refine_compute_ns / 1e3);
            println!("  ssd       : {:>9.1} us ({} reads)", bd.ssd_ns / 1e3, bd.ssd_reads);
            println!("  rerank    : {:>9.1} us", bd.rerank_ns / 1e3);
        }
    }
    println!("\nrecall@10 over {nq} queries: {:.4}", recall / nq as f64);
    Ok(())
}
