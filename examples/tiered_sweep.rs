//! Tiered-memory design-space sweep: how the filter ratio (Fig 8's knob)
//! and the far-memory device parameters trade SSD traffic, latency, and
//! recall. This is the workload a systems engineer would run before
//! provisioning a CXL tier.
//!
//! Run with: `cargo run --release --example tiered_sweep`

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, ground_truth, Pipeline};
use fatrq::metrics::recall_at_k;

fn main() -> anyhow::Result<()> {
    let cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 256,
            count: 30_000,
            clusters: 128,
            noise: 0.35,
            query_noise: 1.0,
            queries: 128,
            seed: 7,
        },
        quant: QuantConfig { pq_m: 32, pq_nbits: 8, kmeans_iters: 8, train_sample: 8192 },
        index: IndexConfig { kind: IndexKind::Ivf, nlist: 128, nprobe: 16, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 200,
            k: 10,
            filter_ratio: 0.25,
            calib_sample: 0.01,
            ..Default::default()
        },
        ..Default::default()
    };
    println!("building 30k x 256D system...");
    let sys = build_system(&cfg)?;
    let truth = ground_truth(&sys, 10);
    let nq = sys.dataset.num_queries();

    // --- Sweep 1: filter ratio (SSD traffic vs recall) ---
    println!("\nfilter-ratio sweep (FaTRQ-HW, 200 candidates):");
    println!("{:>8} {:>10} {:>10} {:>12}", "ratio", "recall@10", "ssd/query", "latency(us)");
    for ratio in [0.02, 0.05, 0.10, 0.20, 0.35, 0.50, 1.00] {
        let mut p = Pipeline::new(&sys);
        p.filter_ratio = ratio;
        let mut recall = 0.0;
        let mut ssd = 0usize;
        let mut lat = 0.0;
        for q in 0..nq {
            let out = p.query(sys.dataset.query(q));
            recall += recall_at_k(&out.topk, &truth[q], 10);
            ssd += out.breakdown.ssd_reads;
            lat += out.breakdown.total_ns();
        }
        println!(
            "{:>8.2} {:>10.4} {:>10.1} {:>12.1}",
            ratio,
            recall / nq as f64,
            ssd as f64 / nq as f64,
            lat / nq as f64 / 1e3
        );
    }

    // --- Sweep 2: CXL link latency (how far can far memory be?) ---
    println!("\nCXL-latency sweep (filter 0.25, SW mode — link on the critical path):");
    println!("{:>12} {:>12}", "link(ns)", "latency(us)");
    for link_ns in [150.0, 271.0, 400.0, 600.0, 1000.0] {
        let mut sim = cfg.sim.clone();
        sim.cxl_latency_ns = link_ns;
        let mut dev = fatrq::simulator::FarMemoryDevice::new(&sim);
        let done = dev.stream_records(0, sys.trq.record_bytes(), 200, 0.0, false);
        println!("{:>12.0} {:>12.2}", link_ns, done / 1e3);
    }

    // --- Sweep 3: candidates (front-stage depth vs recall) ---
    println!("\ncandidate-depth sweep (FaTRQ-HW, filter 0.25):");
    println!("{:>8} {:>10} {:>10}", "cands", "recall@10", "ssd/query");
    for cands in [50usize, 100, 200, 400] {
        let mut p = Pipeline::new(&sys);
        p.candidates = cands;
        let mut recall = 0.0;
        let mut ssd = 0usize;
        for q in 0..nq {
            let out = p.query(sys.dataset.query(q));
            recall += recall_at_k(&out.topk, &truth[q], 10);
            ssd += out.breakdown.ssd_reads;
        }
        println!(
            "{:>8} {:>10.4} {:>10.1}",
            cands,
            recall / nq as f64,
            ssd as f64 / nq as f64
        );
    }
    Ok(())
}
