//! Calibration deep-dive (paper §III-E): why a recall-oriented linear
//! model beats raw distance decomposition, and how little data it needs.
//!
//! Run with: `cargo run --release --example calibration_demo`

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, ground_truth, Pipeline};
use fatrq::metrics::{distance_mse, recall_at_k};
use fatrq::refine::{Calibration, ProgressiveEstimator};
use fatrq::util::l2_sq;

fn main() -> anyhow::Result<()> {
    let mut cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 256,
            count: 25_000,
            clusters: 96,
            noise: 0.35,
            query_noise: 1.0,
            queries: 96,
            seed: 17,
        },
        quant: QuantConfig { pq_m: 32, pq_nbits: 8, kmeans_iters: 8, train_sample: 8192 },
        index: IndexConfig { kind: IndexKind::Ivf, nlist: 96, nprobe: 12, ..Default::default() },
        refine: RefineConfig {
            mode: RefineMode::FatrqSw,
            candidates: 150,
            k: 10,
            filter_ratio: 0.2,
            calib_sample: 0.003,
            ..Default::default()
        },
        ..Default::default()
    };

    println!("building with calib_sample = 0.3% (paper's setting)...");
    let sys = build_system(&cfg)?;
    println!(
        "calibration: {} pairs, train rmse {:.5}",
        sys.cal.pairs, sys.cal.rmse
    );
    println!("weights [d0, d_ip, |δ|², ⟨xc,δ⟩, 1] = {:?}", sys.cal.w);
    println!("(analytic reference would be [1, 1, 1, 2, 0])");

    // --- MSE on held-out query/candidate pairs: analytic vs calibrated ---
    let ana = ProgressiveEstimator::new(&sys.trq, Calibration::analytic());
    let cal = ProgressiveEstimator::new(&sys.trq, sys.cal.clone());
    let mut est_a = Vec::new();
    let mut est_c = Vec::new();
    let mut truths = Vec::new();
    for q in 0..sys.dataset.num_queries() {
        let query = sys.dataset.query(q);
        let qs = sys.scorer.for_query(query);
        for c in sys.index.as_ann().search(query, 100) {
            let id = c.id as usize;
            let d0 = qs.score(id);
            est_a.push(ana.estimate(query, id, d0));
            est_c.push(cal.estimate(query, id, d0));
            truths.push(l2_sq(query, sys.dataset.vector(id)));
        }
    }
    println!("\nheld-out distance MSE:");
    println!("  analytic decomposition : {:.6}", distance_mse(&est_a, &truths));
    println!("  OLS-calibrated         : {:.6}", distance_mse(&est_c, &truths));

    // --- Recall impact through the full pipeline ---
    let truth = ground_truth(&sys, 10);
    let nq = sys.dataset.num_queries();
    println!("\nend-to-end recall@10 at filter ratio 0.2:");
    for (name, weights) in [
        ("analytic", Calibration::analytic()),
        ("calibrated", sys.cal.clone()),
    ] {
        let p = Pipeline::new(&sys);
        let mut recall = 0.0;
        for q in 0..nq {
            let out = p.query_with_cal(sys.dataset.query(q), &weights);
            recall += recall_at_k(&out.topk, &truth[q], 10);
        }
        println!("  {name:>10}: {:.4}", recall / nq as f64);
    }

    // --- Sample-efficiency: how much calibration data is enough? ---
    println!("\nsample-efficiency sweep (rebuild with varying calib_sample):");
    println!("{:>10} {:>8} {:>12}", "sample", "pairs", "train rmse");
    for sample in [0.001, 0.003, 0.01, 0.03] {
        cfg.refine.calib_sample = sample;
        let s = build_system(&cfg)?;
        println!("{:>10.3} {:>8} {:>12.5}", sample, s.cal.pairs, s.cal.rmse);
    }
    Ok(())
}
