//! End-to-end RAG serving driver (the repo's mandated E2E validation).
//!
//! Proves all three layers compose on a real small workload:
//!   L1/L2 — the AOT Pallas/JAX artifacts (`artifacts/*.hlo.txt`) execute
//!           the exact rerank via PJRT from rust;
//!   L3    — the rust coordinator builds a 100k x 768-D corpus, serves
//!           2048 batched queries through the full tiered pipeline in all
//!           three refinement modes, and reports recall / latency / QPS.
//!
//! Run with: `make artifacts && cargo run --release --example rag_serving`
//! (falls back to native rerank if artifacts are missing).
//!
//! Results recorded in EXPERIMENTS.md §End-to-end.

use fatrq::config::{
    DatasetConfig, IndexConfig, IndexKind, QuantConfig, RefineConfig, RefineMode, SystemConfig,
};
use fatrq::coordinator::{build_system, ground_truth, Pipeline, QueryEngine, ShardedEngine};
use fatrq::metrics::{recall_at_k, LatencyStats};
use fatrq::runtime::XlaRuntime;
use fatrq::util::l2_sq;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let scale: usize = std::env::var("RAG_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100_000);
    let queries: usize = std::env::var("RAG_QUERIES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(2048);

    let cfg = SystemConfig {
        dataset: DatasetConfig {
            dim: 768,
            count: scale,
            clusters: 512,
            noise: 0.35,
            query_noise: 1.0,
            queries,
            seed: 2026,
        },
        quant: QuantConfig { pq_m: 96, pq_nbits: 8, kmeans_iters: 6, train_sample: 8192 },
        index: IndexConfig {
            kind: IndexKind::Ivf,
            nlist: 256,
            nprobe: 16,
            ..Default::default()
        },
        refine: RefineConfig {
            mode: RefineMode::FatrqHw,
            candidates: 320, // paper §V-B: IVF refines ~320/query at 90% recall
            k: 10,
            filter_ratio: 0.1,
            calib_sample: 0.003, // the paper's 0.3%
            ..Default::default()
        },
        ..Default::default()
    };

    println!("=== FaTRQ end-to-end RAG serving driver ===");
    println!("corpus: {} x {}D, {} queries", scale, cfg.dataset.dim, queries);
    let t0 = std::time::Instant::now();
    let sys = build_system(&cfg)?;
    println!("system built in {:.1}s", t0.elapsed().as_secs_f64());
    println!(
        "  fast {:.1} MiB | far {:.1} MiB | storage {:.1} MiB",
        (sys.scorer.fast_bytes() + sys.index.fast_bytes()) as f64 / (1 << 20) as f64,
        sys.trq.far_bytes() as f64 / (1 << 20) as f64,
        (scale * 768 * 4) as f64 / (1 << 20) as f64
    );

    // --- L1/L2 composition proof: PJRT rerank vs native on real data ---
    let artifacts = Path::new("artifacts");
    match XlaRuntime::load(artifacts) {
        Ok(rt) => {
            let q = sys.dataset.query(0);
            let ids: Vec<usize> = (0..32).collect();
            let mut vectors = vec![0f32; ids.len() * 768];
            for (j, &i) in ids.iter().enumerate() {
                vectors[j * 768..(j + 1) * 768].copy_from_slice(sys.dataset.vector(i));
            }
            let xla_d = rt.rerank_block(q, &vectors)?;
            let mut max_err = 0f32;
            for (j, &i) in ids.iter().enumerate() {
                let native = l2_sq(q, sys.dataset.vector(i));
                max_err = max_err.max((xla_d[j] - native).abs() / native.max(1e-6));
            }
            println!("PJRT rerank vs native: max rel err {max_err:.2e} (AOT path live)");

            // And the TRQ refinement executable against the host estimator.
            let pipeline = Pipeline::new(&sys);
            let cands = sys.index.as_ann().search(q, 64);
            let d0: Vec<f32> = cands.iter().map(|c| c.dist).collect();
            let mut packed = Vec::new();
            let mut scale_v = Vec::new();
            let mut cross = Vec::new();
            let mut dn = Vec::new();
            for c in &cands {
                let id = c.id as usize;
                packed.extend_from_slice(sys.trq.packed_row(id));
                scale_v.push(sys.trq.scale[id]);
                cross.push(sys.trq.cross[id]);
                dn.push(sys.trq.dnorm_sq[id]);
            }
            let xla_est = rt.refine_block(q, &sys.cal.w, &d0, &packed, &scale_v, &cross, &dn)?;
            let est = fatrq::refine::ProgressiveEstimator::new(&sys.trq, sys.cal.clone());
            let mut max_err = 0f32;
            for (j, c) in cands.iter().enumerate() {
                let native = est.estimate(q, c.id as usize, c.dist);
                max_err = max_err.max((xla_est[j] - native).abs());
            }
            println!("PJRT trq_refine vs host estimator: max abs err {max_err:.2e}");
            let _ = pipeline;
        }
        Err(e) => println!("(artifacts not available, native-only run: {e})"),
    }

    // --- Serve the full query load in each mode, through the persistent
    // engine: one thread pool + per-worker scratch for all runs ---
    println!("\ncomputing exact ground truth...");
    let truth = ground_truth(&sys, 10);
    let threads = fatrq::util::threadpool::default_threads();
    let sys = Arc::new(sys);
    let engine = QueryEngine::with_threads(Arc::clone(&sys), threads);
    println!(
        "\n{:>12} {:>9} {:>11} {:>11} {:>11} {:>9} {:>9} {:>7} {:>7}",
        "mode", "recall@10", "p50(us)", "p99(us)", "mean(us)", "model-qps", "wall-qps", "far/q", "ssd/q"
    );
    let mut base_lat = 0.0;
    for (label, mode, early_exit) in [
        ("baseline", RefineMode::Baseline, false),
        ("fatrq-sw", RefineMode::FatrqSw, false),
        ("fatrq-hw", RefineMode::FatrqHw, false),
        ("fatrq-hw+ee", RefineMode::FatrqHw, true),
    ] {
        let params = engine.params().with_mode(mode).with_early_exit(early_exit);
        let wall0 = std::time::Instant::now();
        let outs = engine.run_with(&params, &sys.dataset.queries);
        let wall_s = wall0.elapsed().as_secs_f64();
        let nq = outs.len();
        let mut lat = LatencyStats::default();
        let mut recall = 0.0;
        let (mut far_q, mut ssd_q) = (0usize, 0usize);
        for (q, out) in outs.iter().enumerate() {
            recall += recall_at_k(&out.topk, &truth[q], 10);
            lat.record(out.breakdown.total_ns());
            far_q += out.breakdown.far_reads;
            ssd_q += out.breakdown.ssd_reads;
        }
        let mean = lat.mean();
        if mode == RefineMode::Baseline {
            base_lat = mean;
        }
        println!(
            "{:>12} {:>9.4} {:>11.1} {:>11.1} {:>11.1} {:>9.0} {:>9.0} {:>7} {:>7}   ({:.2}x)",
            label,
            recall / nq as f64,
            lat.p50() / 1e3,
            lat.p99() / 1e3,
            mean / 1e3,
            threads as f64 * 1e9 / mean.max(1e-9),
            nq as f64 / wall_s.max(1e-12),
            far_q / nq,
            ssd_q / nq,
            base_lat / mean.max(1e-9)
        );
    }

    // --- Sharded scatter/gather over the same corpus, one shared
    // far-memory device: the contention-honest batch-serving numbers ---
    let shards = 4usize;
    println!("\nbuilding {shards}-shard scatter/gather engine over the same corpus...");
    let t0 = std::time::Instant::now();
    let mut sharded = ShardedEngine::from_dataset(&cfg, &sys.dataset, shards)?;
    println!("shards built in {:.1}s", t0.elapsed().as_secs_f64());
    println!(
        "\n{:>14} {:>9} {:>11} {:>11} {:>9} {:>9} {:>7}",
        "serving", "recall@10", "p50(us)", "p99(us)", "queue(us)", "wall-qps", "far/q"
    );
    for contention in [false, true] {
        sharded.set_shared_timeline(contention);
        let wall0 = std::time::Instant::now();
        let outs = sharded.run(&sys.dataset.queries);
        let wall_s = wall0.elapsed().as_secs_f64();
        let nq = outs.len();
        let mut lat = LatencyStats::default();
        let (mut recall, mut queue, mut far_q) = (0.0f64, 0.0f64, 0usize);
        for (q, out) in outs.iter().enumerate() {
            recall += recall_at_k(&out.topk, &truth[q], 10);
            lat.record(out.breakdown.total_ns());
            queue += out.breakdown.queue_ns;
            far_q += out.breakdown.far_reads;
        }
        println!(
            "{:>14} {:>9.4} {:>11.1} {:>11.1} {:>9.1} {:>9.0} {:>7}",
            if contention { "4sh contended" } else { "4sh idle-dev" },
            recall / nq as f64,
            lat.p50() / 1e3,
            lat.p99() / 1e3,
            queue / nq as f64 / 1e3,
            nq as f64 / wall_s.max(1e-12),
            far_q / nq
        );
    }
    println!("\ndone.");
    Ok(())
}
