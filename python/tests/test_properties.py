"""Hypothesis sweeps over kernel shapes/values: the Pallas kernels must
match their jnp oracles for arbitrary valid inputs, and the oracles must
satisfy algebraic invariants of the paper's estimator."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.exact_l2 import exact_l2
from compile.kernels.pq_adc import pq_adc
from compile.kernels.trq_refine import trq_refine

# Keep each case fast: interpret-mode pallas is numpy-speed.
FAST = settings(max_examples=25, deadline=None)


def np_rng(seed):
    return np.random.default_rng(seed)


@st.composite
def adc_case(draw):
    m = draw(st.sampled_from([2, 4, 8, 16]))
    ksub = draw(st.sampled_from([2, 4, 16, 64]))
    n = draw(st.sampled_from([32, 64, 256, 512]))
    seed = draw(st.integers(0, 2**32 - 1))
    rng = np_rng(seed)
    lut = rng.standard_normal((m, ksub)).astype(np.float32)
    codes = rng.integers(0, ksub, size=(n, m)).astype(np.int32)
    return lut, codes


@FAST
@given(adc_case())
def test_pq_adc_matches_ref_any_shape(case):
    lut, codes = case
    got = np.asarray(pq_adc(jnp.array(lut), jnp.array(codes)))
    want = np.asarray(ref.pq_adc_ref(jnp.array(lut), jnp.array(codes)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@st.composite
def refine_case(draw):
    dim = draw(st.sampled_from([5, 16, 33, 64, 160, 768]))
    n = draw(st.sampled_from([32, 64, 256]))
    seed = draw(st.integers(0, 2**32 - 1))
    rng = np_rng(seed)
    pbytes = ref.packed_len(dim)
    trits = rng.integers(-1, 2, size=(n, pbytes * 5))
    trits[:, dim:] = 0
    powers = np.array([1, 3, 9, 27, 81])
    packed = ((trits.reshape(n, pbytes, 5) + 1) * powers).sum(axis=2).astype(np.int32)
    return dict(
        dim=dim,
        query=rng.standard_normal(dim).astype(np.float32),
        weights=rng.standard_normal(5).astype(np.float32),
        d0=rng.uniform(0, 4, n).astype(np.float32),
        packed=packed,
        scale=rng.uniform(0.01, 1.0, n).astype(np.float32),
        cross=(rng.standard_normal(n) * 0.1).astype(np.float32),
        dnorm_sq=rng.uniform(0, 1, n).astype(np.float32),
    )


@FAST
@given(refine_case())
def test_trq_refine_matches_ref_any_shape(kw):
    dim = kw.pop("dim")
    args = {k: jnp.array(v) for k, v in kw.items()}
    got = np.asarray(trq_refine(dim=dim, **args))
    want = np.asarray(
        ref.trq_refine_ref(
            args["query"], args["d0"], args["packed"], args["scale"],
            args["cross"], args["dnorm_sq"], args["weights"], dim,
        )
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@FAST
@given(refine_case())
def test_refine_linear_in_weights(kw):
    """The estimator is linear in W: f(aW1 + bW2) = a f(W1) + b f(W2)."""
    dim = kw.pop("dim")
    args = {k: jnp.array(v) for k, v in kw.items()}
    w1 = args["weights"]
    w2 = jnp.flip(w1)
    run = lambda w: np.asarray(
        ref.trq_refine_ref(
            args["query"], args["d0"], args["packed"], args["scale"],
            args["cross"], args["dnorm_sq"], w, dim,
        )
    )
    lhs = run(0.3 * w1 + 0.7 * w2)
    rhs = 0.3 * run(w1) + 0.7 * run(w2)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


@FAST
@given(st.integers(0, 2**32 - 1), st.sampled_from([16, 64, 768]),
       st.sampled_from([32, 64]))
def test_exact_l2_matches_ref(seed, dim, n):
    rng = np_rng(seed)
    q = jnp.array(rng.standard_normal(dim), dtype=jnp.float32)
    v = jnp.array(rng.standard_normal((n, dim)), dtype=jnp.float32)
    got = np.asarray(exact_l2(q, v))
    want = np.asarray(ref.exact_l2_ref(q, v))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@FAST
@given(st.integers(0, 2**32 - 1), st.sampled_from([7, 40, 768]))
def test_unpack_is_left_inverse_of_pack(seed, dim):
    rng = np_rng(seed)
    pbytes = ref.packed_len(dim)
    trits = rng.integers(-1, 2, size=(8, pbytes * 5))
    trits[:, dim:] = 0
    powers = np.array([1, 3, 9, 27, 81])
    packed = ((trits.reshape(8, pbytes, 5) + 1) * powers).sum(axis=2)
    got = np.asarray(ref.unpack_ternary_ref(jnp.array(packed.astype(np.int32)), dim))
    np.testing.assert_array_equal(got, trits[:, :dim])


@FAST
@given(st.integers(0, 2**32 - 1))
def test_qdot_scale_equivariance(seed):
    """⟨q, δ⟩ estimate scales linearly with both query and record scale."""
    rng = np_rng(seed)
    dim, n = 30, 16
    pbytes = ref.packed_len(dim)
    trits = rng.integers(-1, 2, size=(n, pbytes * 5))
    trits[:, dim:] = 0
    powers = np.array([1, 3, 9, 27, 81])
    packed = jnp.array(
        ((trits.reshape(n, pbytes, 5) + 1) * powers).sum(axis=2).astype(np.int32)
    )
    q = jnp.array(rng.standard_normal(dim), dtype=jnp.float32)
    scale = jnp.array(rng.uniform(0.1, 1.0, n), dtype=jnp.float32)
    base = np.asarray(ref.trq_qdot_ref(q, packed, scale, dim))
    doubled_q = np.asarray(ref.trq_qdot_ref(2.0 * q, packed, scale, dim))
    doubled_s = np.asarray(ref.trq_qdot_ref(q, packed, 2.0 * scale, dim))
    np.testing.assert_allclose(doubled_q, 2 * base, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(doubled_s, 2 * base, rtol=1e-4, atol=1e-6)
