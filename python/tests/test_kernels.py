"""Kernel-vs-reference correctness: every Pallas kernel against its
pure-jnp oracle, plus numpy cross-checks of the oracles themselves."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels import ref
from compile.kernels.exact_l2 import exact_l2
from compile.kernels.pq_adc import pq_adc
from compile.kernels.trq_refine import trq_refine

RNG = np.random.default_rng(42)


def random_packed(n, dim):
    """Random base-3 packed codes [n, pbytes] plus their trits [n, dim]."""
    pbytes = ref.packed_len(dim)
    trits = RNG.integers(-1, 2, size=(n, pbytes * ref.TRITS_PER_BYTE))
    trits[:, dim:] = 0
    powers = np.array([1, 3, 9, 27, 81])
    packed = ((trits.reshape(n, pbytes, 5) + 1) * powers).sum(axis=2)
    return packed.astype(np.int32), trits[:, :dim].astype(np.int8)


class TestOracles:
    """The jnp references against straight numpy."""

    def test_pq_adc_ref_vs_numpy(self):
        m, ksub, n = 8, 16, 32
        lut = RNG.standard_normal((m, ksub)).astype(np.float32)
        codes = RNG.integers(0, ksub, size=(n, m)).astype(np.int32)
        got = np.asarray(ref.pq_adc_ref(jnp.array(lut), jnp.array(codes)))
        want = np.array(
            [sum(lut[j, codes[i, j]] for j in range(m)) for i in range(n)]
        )
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_unpack_ternary_ref_roundtrip(self):
        for dim in [5, 7, 64, 768]:
            packed, trits = random_packed(10, dim)
            got = np.asarray(ref.unpack_ternary_ref(jnp.array(packed), dim))
            np.testing.assert_array_equal(got, trits)

    def test_trq_qdot_ref_vs_numpy(self):
        dim, n = 64, 16
        packed, trits = random_packed(n, dim)
        q = RNG.standard_normal(dim).astype(np.float32)
        scale = RNG.uniform(0.1, 2.0, n).astype(np.float32)
        got = np.asarray(
            ref.trq_qdot_ref(jnp.array(q), jnp.array(packed), jnp.array(scale), dim)
        )
        k = np.abs(trits).sum(axis=1)
        want = np.where(
            k > 0, (trits @ q) * scale / np.sqrt(np.maximum(k, 1)), 0.0
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)

    def test_exact_l2_ref(self):
        q = RNG.standard_normal(32).astype(np.float32)
        v = RNG.standard_normal((10, 32)).astype(np.float32)
        got = np.asarray(ref.exact_l2_ref(jnp.array(q), jnp.array(v)))
        want = ((v - q) ** 2).sum(axis=1)
        np.testing.assert_allclose(got, want, rtol=1e-5)


class TestPqAdcKernel:
    @pytest.mark.parametrize("n,m,ksub", [(256, 96, 256), (512, 8, 16), (64, 4, 4)])
    def test_matches_ref(self, n, m, ksub):
        lut = jnp.array(RNG.standard_normal((m, ksub)), dtype=jnp.float32)
        codes = jnp.array(RNG.integers(0, ksub, size=(n, m)), dtype=jnp.int32)
        got = pq_adc(lut, codes)
        want = ref.pq_adc_ref(lut, codes)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

    def test_multiblock_grid(self):
        # n > BLOCK_N exercises the grid/BlockSpec streaming path.
        n, m, ksub = 1024, 16, 32
        lut = jnp.array(RNG.standard_normal((m, ksub)), dtype=jnp.float32)
        codes = jnp.array(RNG.integers(0, ksub, size=(n, m)), dtype=jnp.int32)
        got = pq_adc(lut, codes)
        want = ref.pq_adc_ref(lut, codes)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


class TestTrqRefineKernel:
    def _inputs(self, n, dim):
        packed, _ = random_packed(n, dim)
        return dict(
            query=jnp.array(RNG.standard_normal(dim), dtype=jnp.float32),
            weights=jnp.array(RNG.standard_normal(5), dtype=jnp.float32),
            d0=jnp.array(RNG.uniform(0, 4, n), dtype=jnp.float32),
            packed=jnp.array(packed),
            scale=jnp.array(RNG.uniform(0.05, 1.0, n), dtype=jnp.float32),
            cross=jnp.array(RNG.standard_normal(n) * 0.1, dtype=jnp.float32),
            dnorm_sq=jnp.array(RNG.uniform(0, 1, n), dtype=jnp.float32),
        )

    @pytest.mark.parametrize("n,dim", [(256, 768), (512, 768), (64, 60), (128, 33)])
    def test_matches_ref(self, n, dim):
        kw = self._inputs(n, dim)
        got = trq_refine(dim=dim, **kw)
        want = ref.trq_refine_ref(
            kw["query"], kw["d0"], kw["packed"], kw["scale"], kw["cross"],
            kw["dnorm_sq"], kw["weights"], dim,
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
        )

    def test_analytic_weights_reproduce_decomposition(self):
        # With W = [1,1,1,2,0] the kernel must equal
        # d0 + (-2 qdot) + ||δ||² + 2<x_c, δ>.
        n, dim = 256, 64
        kw = self._inputs(n, dim)
        kw["weights"] = jnp.array([1.0, 1.0, 1.0, 2.0, 0.0])
        got = np.asarray(trq_refine(dim=dim, **kw))
        qdot = np.asarray(
            ref.trq_qdot_ref(kw["query"], kw["packed"], kw["scale"], dim)
        )
        want = (
            np.asarray(kw["d0"]) - 2 * qdot + np.asarray(kw["dnorm_sq"])
            + 2 * np.asarray(kw["cross"])
        )
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    def test_zero_code_contributes_nothing(self):
        n, dim = 256, 40
        kw = self._inputs(n, dim)
        kw["packed"] = jnp.array(
            np.full((n, ref.packed_len(dim)), 121, dtype=np.int32)
        )  # 121 = all-zero trits (1+3+9+27+81)
        kw["weights"] = jnp.array([0.0, 1.0, 0.0, 0.0, 0.0])
        got = np.asarray(trq_refine(dim=dim, **kw))
        np.testing.assert_allclose(got, np.zeros(n), atol=1e-7)


class TestExactL2Kernel:
    @pytest.mark.parametrize("n,dim", [(64, 768), (128, 768), (32, 17)])
    def test_matches_ref(self, n, dim):
        q = jnp.array(RNG.standard_normal(dim), dtype=jnp.float32)
        v = jnp.array(RNG.standard_normal((n, dim)), dtype=jnp.float32)
        got = exact_l2(q, v)
        want = ref.exact_l2_ref(q, v)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
        )

    def test_zero_distance_to_self(self):
        q = jnp.array(RNG.standard_normal(64), dtype=jnp.float32)
        v = jnp.tile(q[None, :], (64, 1))
        got = np.asarray(exact_l2(q, v))
        np.testing.assert_allclose(got, np.zeros(64), atol=1e-5)
