"""AOT path tests: every entry point lowers to parseable HLO text with the
declared shapes, and the lowered graphs compute the same numbers as the
eager kernels (executed via jax on CPU)."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def test_entry_points_lower_to_hlo_text():
    for name, fn, example_args in aot.entry_points():
        lowered = jax.jit(fn).lower(*example_args)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: no HloModule header"
        assert "ROOT" in text, f"{name}: no ROOT instruction"
        # return_tuple=True -> tuple-shaped root
        assert "(f32[" in text, f"{name}: root is not a tuple of f32"


def test_manifest_consistent_with_entry_points():
    text = aot.manifest_text()
    assert f"dim = {aot.DIM}" in text
    assert f"refine_n = {aot.REFINE_N}" in text
    assert f"packed_bytes = {ref.packed_len(aot.DIM)}" in text


def test_compiled_coarse_scan_matches_ref():
    rng = np.random.default_rng(7)
    lut = jnp.array(
        rng.standard_normal((aot.PQ_M, aot.PQ_KSUB)), dtype=jnp.float32
    )
    codes = jnp.array(
        rng.integers(0, aot.PQ_KSUB, size=(aot.SCAN_N, aot.PQ_M)),
        dtype=jnp.int32,
    )
    compiled = jax.jit(model.coarse_scan).lower(lut, codes).compile()
    (got,) = compiled(lut, codes)
    want = ref.pq_adc_ref(lut, codes)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def test_compiled_refine_block_matches_ref():
    rng = np.random.default_rng(8)
    n, dim = aot.REFINE_N, aot.DIM
    pbytes = ref.packed_len(dim)
    trits = rng.integers(-1, 2, size=(n, pbytes * 5))
    trits[:, dim:] = 0
    powers = np.array([1, 3, 9, 27, 81])
    packed = jnp.array(
        ((trits.reshape(n, pbytes, 5) + 1) * powers).sum(axis=2).astype(np.int32)
    )
    args = (
        jnp.array(rng.standard_normal(dim), dtype=jnp.float32),
        jnp.array([1.0, 1.0, 1.0, 2.0, 0.0], dtype=jnp.float32),
        jnp.array(rng.uniform(0, 4, n), dtype=jnp.float32),
        packed,
        jnp.array(rng.uniform(0.01, 1, n), dtype=jnp.float32),
        jnp.array(rng.standard_normal(n) * 0.1, dtype=jnp.float32),
        jnp.array(rng.uniform(0, 1, n), dtype=jnp.float32),
    )
    compiled = jax.jit(model.refine_block).lower(*args).compile()
    (got,) = compiled(*args)
    want = ref.trq_refine_ref(
        args[0], args[2], args[3], args[4], args[5], args[6], args[1], dim
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )


def test_compiled_rerank_matches_ref():
    rng = np.random.default_rng(9)
    q = jnp.array(rng.standard_normal(aot.DIM), dtype=jnp.float32)
    v = jnp.array(
        rng.standard_normal((aot.RERANK_N, aot.DIM)), dtype=jnp.float32
    )
    compiled = jax.jit(model.rerank_block).lower(q, v).compile()
    (got,) = compiled(q, v)
    want = ref.exact_l2_ref(q, v)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5
    )
