"""L2: the JAX compute graphs AOT-compiled for the rust coordinator.

Three entry points, each calling its L1 Pallas kernel so the kernel
lowers into the same HLO module:

  - coarse_scan:    per-query ADC over a candidate code block
  - refine_block:   FaTRQ progressive refinement of a candidate block
  - rerank_block:   exact L2 over SSD-fetched survivors

Shapes are fixed at AOT time (PJRT executables are static); the rust
runtime pads batches to the compiled block size (see
rust/src/runtime/executor.rs).
"""

from compile.kernels.exact_l2 import exact_l2
from compile.kernels.pq_adc import pq_adc
from compile.kernels.trq_refine import trq_refine


def coarse_scan(lut, codes):
    """Front-stage ADC scan. lut [m, ksub] f32, codes [n, m] i32 -> [n]."""
    return (pq_adc(lut, codes),)


def refine_block(query, weights, d0, packed, scale, cross, dnorm_sq):
    """FaTRQ refinement. See kernels.trq_refine for shapes. -> [n]."""
    dim = query.shape[0]
    return (
        trq_refine(query, weights, d0, packed, scale, cross, dnorm_sq, dim=dim),
    )


def rerank_block(query, vectors):
    """Exact rerank. query [dim], vectors [n, dim] -> [n]."""
    return (exact_l2(query, vectors),)
