"""Pure-jnp reference oracles for the L1 Pallas kernels.

Everything here is straight-line jax.numpy with no pallas — the ground
truth the kernels (and, transitively, the AOT artifacts executed from
rust) are tested against.

Conventions (shared with the rust side, see rust/src/quant/):
  - PQ codes:   uint8 [n, m], LUT float32 [m, ksub]
  - TRQ codes:  packed uint8 [n, pbytes] (5 base-3 trits per byte)
  - metadata:   float32 scale[n] (= ||delta||*alignment), cross[n],
                dnorm_sq[n]
  - calibration: float32 [5] for [d0, d_ip, dnorm_sq, cross, 1]
"""

import jax.numpy as jnp

TRITS_PER_BYTE = 5


def packed_len(dim: int) -> int:
    """Packed byte length for `dim` trits."""
    return -(-dim // TRITS_PER_BYTE)


def pq_adc_ref(lut, codes):
    """ADC distances: sum LUT[sub, codes[i, sub]] over subspaces.

    lut:   [m, ksub] float32
    codes: [n, m] uint8 (or int32)
    returns [n] float32
    """
    m = lut.shape[0]
    sub_idx = jnp.arange(m)
    # gather per row: lut[sub, code] for each (row, sub)
    return jnp.sum(lut[sub_idx[None, :], codes.astype(jnp.int32)], axis=1)


def unpack_ternary_ref(packed, dim: int):
    """Unpack base-3 bytes to trits in {-1, 0, 1}.

    packed: [n, pbytes] uint8
    returns [n, dim] int8
    """
    n, pbytes = packed.shape
    assert pbytes == packed_len(dim)
    # positions 0..4 within each byte: value // 3^i % 3 - 1
    powers = jnp.array([1, 3, 9, 27, 81], dtype=jnp.int32)
    digits = (packed[:, :, None].astype(jnp.int32) // powers[None, None, :]) % 3 - 1
    trits = digits.reshape(n, pbytes * TRITS_PER_BYTE)
    return trits[:, :dim].astype(jnp.int8)


def trq_qdot_ref(query, packed, scale, dim: int):
    """FaTRQ residual inner-product estimate ⟨q, δ⟩ per record.

    query:  [dim] float32
    packed: [n, pbytes] uint8
    scale:  [n] float32 (= ||delta|| * alignment)
    returns [n] float32
    """
    trits = unpack_ternary_ref(packed, dim).astype(jnp.float32)
    acc = trits @ query  # [n]
    k = jnp.sum(jnp.abs(trits), axis=1)  # nonzero count
    safe_k = jnp.maximum(k, 1.0)
    return jnp.where(k > 0, acc * scale / jnp.sqrt(safe_k), 0.0)


def trq_refine_ref(query, d0, packed, scale, cross, dnorm_sq, weights, dim: int):
    """Full refined distance estimate (paper §III-E).

    Features A = [d0, -2*qdot, dnorm_sq, cross, 1]; returns A @ weights.

    query: [dim], d0: [n], packed: [n, pbytes], scale/cross/dnorm_sq: [n],
    weights: [5]. Returns [n] float32.
    """
    qdot = trq_qdot_ref(query, packed, scale, dim)
    feats = jnp.stack(
        [d0, -2.0 * qdot, dnorm_sq, cross, jnp.ones_like(d0)], axis=1
    )  # [n, 5]
    return feats @ weights


def exact_l2_ref(query, vectors):
    """Exact squared-L2 rerank distances.

    query: [dim], vectors: [n, dim]. Returns [n] float32.
    """
    diff = vectors - query[None, :]
    return jnp.sum(diff * diff, axis=1)
