"""L1 Pallas kernel: PQ asymmetric-distance (ADC) scan.

The front-stage hot loop: for every candidate code row, sum the per-
subspace LUT entries. On the paper's GPU this is the table-lookup kernel
cuVS/FAISS run in VRAM; the TPU adaptation (DESIGN.md §2) keeps the whole
[m, ksub] LUT resident in VMEM (96x256 f32 = 96 KiB « 16 MiB VMEM) and
streams candidate code blocks HBM→VMEM via BlockSpec, so each block's
scan is arithmetic-only.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Candidate rows per grid step. 256 rows x 96 subspaces x 4 B codes is a
# 96 KiB VMEM tile for the codes + the resident LUT; comfortably on-chip.
BLOCK_N = 256


def _adc_kernel(lut_ref, codes_ref, o_ref):
    """One block: gather-sum LUT rows for BLOCK_N candidates."""
    lut = lut_ref[...]  # [m, ksub] resident
    codes = codes_ref[...]  # [block, m] int32
    m = lut.shape[0]
    # Per-subspace gather. On a real TPU this lowers to a one-hot matmul
    # feeding the MXU; under interpret it is a plain vectorized gather.
    sub = jnp.arange(m)
    vals = lut[sub[None, :], codes]  # [block, m]
    o_ref[...] = jnp.sum(vals, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pq_adc(lut, codes, *, interpret=True):
    """ADC distances for a padded candidate block.

    lut:   [m, ksub] float32 — per-query subspace distance table
    codes: [n, m] int32 — PQ codes (n must be a multiple of BLOCK_N, or
           n < BLOCK_N for a single-block call)
    returns [n] float32
    """
    n, m = codes.shape
    block = min(BLOCK_N, n)
    assert n % block == 0, f"n={n} must be a multiple of {block}"
    grid = (n // block,)
    return pl.pallas_call(
        _adc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(lut.shape, lambda i: (0, 0)),  # LUT resident
            pl.BlockSpec((block, m), lambda i: (i, 0)),  # stream codes
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(lut, codes)
