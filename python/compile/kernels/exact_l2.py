"""L1 Pallas kernel: exact squared-L2 rerank.

The final stage: the few SSD-fetched survivors are scored exactly. Query
resident in VMEM, full-precision vectors streamed per block.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 64


def _l2_kernel(q_ref, v_ref, o_ref):
    q = q_ref[...]  # [dim]
    v = v_ref[...]  # [block, dim]
    diff = v - q[None, :]
    o_ref[...] = jnp.sum(diff * diff, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def exact_l2(query, vectors, *, interpret=True):
    """Exact squared distances.

    query:   [dim] float32
    vectors: [n, dim] float32 (n a multiple of min(BLOCK_N, n))
    returns  [n] float32
    """
    n, dim = vectors.shape
    block = min(BLOCK_N, n)
    assert n % block == 0, f"n={n} must be a multiple of {block}"
    grid = (n // block,)
    return pl.pallas_call(
        _l2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(query.shape, lambda i: (0,)),
            pl.BlockSpec((block, dim), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(query, vectors)
