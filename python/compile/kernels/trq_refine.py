"""L1 Pallas kernel: FaTRQ progressive refinement (the paper's §III-E hot
spot).

Per candidate: unpack the base-3 packed ternary residual code (the
accelerator's 256-entry LUT becomes arithmetic digit extraction here),
accumulate the query inner product (adds/subs only — the trits are
{-1,0,1}), rescale by the record's alignment-folded norm, and emit the
calibrated 5-feature dot product.

TPU adaptation (DESIGN.md §2): the query vector and calibration weights
are VMEM-resident; candidate records (packed codes + 3 scalars) stream
through in blocks. Per 768-D candidate a block row is 154 packed bytes —
the same 162-B record the CXL device streams, so the BlockSpec expresses
exactly the paper's far-memory access pattern.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 256
TRITS_PER_BYTE = 5
_POWERS = (1, 3, 9, 27, 81)


def _refine_kernel(q_ref, w_ref, d0_ref, packed_ref, scale_ref, cross_ref,
                   dnorm_ref, o_ref, *, dim):
    q = q_ref[...]  # [dim]
    w = w_ref[...]  # [5]
    d0 = d0_ref[...]  # [block]
    packed = packed_ref[...]  # [block, pbytes] int32
    scale = scale_ref[...]
    cross = cross_ref[...]
    dnorm_sq = dnorm_ref[...]

    block, pbytes = packed.shape
    # Unpack base-3 digits -> trits in {-1,0,1}. Scalar constants only:
    # pallas kernels may not capture constant arrays, and the unrolled
    # divide/mod chain is exactly what the accelerator's decode LUT does.
    cols = []
    x = packed
    for _ in range(TRITS_PER_BYTE):
        cols.append(x % 3 - 1)
        x = x // 3
    digits = jnp.stack(cols, axis=-1)  # [block, pbytes, 5]
    trits = digits.reshape(block, pbytes * TRITS_PER_BYTE)[:, :dim]
    tf = trits.astype(jnp.float32)
    # Multiplication-free inner product (adds/subs in hardware).
    acc = tf @ q  # [block]
    k = jnp.sum(jnp.abs(tf), axis=1)  # nonzero count = k*
    qdot = jnp.where(k > 0, acc * scale / jnp.sqrt(jnp.maximum(k, 1.0)), 0.0)
    # Calibrated estimate: A @ W with A = [d0, -2*qdot, dnorm_sq, cross, 1].
    o_ref[...] = (
        d0 * w[0]
        - 2.0 * qdot * w[1]
        + dnorm_sq * w[2]
        + cross * w[3]
        + w[4]
    )


@functools.partial(jax.jit, static_argnames=("dim", "interpret"))
def trq_refine(query, weights, d0, packed, scale, cross, dnorm_sq, *,
               dim, interpret=True):
    """Refined distance estimates for a padded candidate block.

    query:    [dim] float32
    weights:  [5] float32 calibration (use [1,1,1,2,0] for the analytic
              decomposition)
    d0:       [n] float32 coarse ADC distances
    packed:   [n, pbytes] int32 base-3 packed ternary codes
    scale:    [n] float32 — ||delta|| * alignment
    cross:    [n] float32 — <x_c, delta>
    dnorm_sq: [n] float32 — ||delta||^2
    returns   [n] float32 refined estimates
    """
    n, pbytes = packed.shape
    block = min(BLOCK_N, n)
    assert n % block == 0, f"n={n} must be a multiple of {block}"
    grid = (n // block,)
    kernel = functools.partial(_refine_kernel, dim=dim)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(query.shape, lambda i: (0,)),  # query resident
            pl.BlockSpec(weights.shape, lambda i: (0,)),  # weights resident
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block, pbytes), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(query, weights, d0, packed, scale, cross, dnorm_sq)
